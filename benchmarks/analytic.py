"""LoongTrain §4.5 cost model instantiated with TPU v5e constants.

The paper evaluates on A100 + 4×HDR nodes; we target a v5e pod, so the
model is re-based on ICI:

* peak = 197 TF/s bf16/chip;  per-link ICI = 50 GB/s.
* "intra-node NVLINK" ≙ collectives over the ICI-*minor* mesh axis
  (single-hop neighbours): full link bw.
* "inter-node NIC"    ≙ collectives over major axes: modelled at half
  effective bw (multi-hop average on the torus) — the placement trade-off
  of §4.4 survives with the same structure.
* Double ring: inner ring uses one torus dimension, outer the other; both
  can run concurrently (the "use all NICs" insight).

These formulas power benchmarks that mirror the paper's Tables 2-5.  They
are *models*, cross-checked against dry-run collective bytes (see
EXPERIMENTS.md §Roofline); wall-time numbers on real v5e would calibrate α.
"""
from __future__ import annotations

import dataclasses

PEAK = 197e12          # bf16 FLOP/s per chip
ICI = 50e9             # B/s per link
MAJOR_PENALTY = 0.5    # effective bw multiplier for ICI-major-axis traffic
BYTES = 2              # bf16


@dataclasses.dataclass(frozen=True)
class AttnCase:
    s: int                 # sequence length
    d: int = 4096          # hidden
    h: int = 32            # query heads
    h_kv: int = 32         # kv heads (MHA: == h)
    sp: int = 64           # total sequence-parallel degree
    hp: int = 1
    w: int = 4             # inner ring size
    placement: str = "head_first"
    causal: bool = True

    @property
    def cp(self) -> int:
        return self.sp // self.hp

    @property
    def hd(self) -> int:
        return self.d // self.h


def attn_flops_per_device(c: AttnCase) -> float:
    """Useful attention FLOPs per device per layer fwd (causal halved)."""
    full = 4.0 * c.s * c.s * c.d          # QK^T + PV, MACs×2
    if c.causal:
        full *= 0.5
    return full / c.sp


def comp_time_fwd(c: AttnCase) -> float:
    """One ring micro-step of compute (paper: α S²D/(cp·sp))."""
    per_step = attn_flops_per_device(c) / c.cp
    return per_step / PEAK


def kv_chunk_bytes(c: AttnCase) -> float:
    """Paper §4.5.3: Size(kv) = max(Hkv, hp)/H × (2 tensors)·S·D/sp ·bytes."""
    h_eff = max(c.h_kv, c.hp)
    return h_eff / c.h * 2.0 * c.s * c.d / c.sp * BYTES


def p2p_time(c: AttnCase, *, inner: bool) -> float:
    bw = ICI
    # context-first: inner ring is ICI-minor (full bw); head-first: the head
    # axis is minor, pushing rings to major axes.
    if c.placement == "context_first":
        if not inner:
            bw *= MAJOR_PENALTY
    else:
        bw *= MAJOR_PENALTY
    return kv_chunk_bytes(c) / bw


def alltoall_time(c: AttnCase) -> float:
    """Paper §4.5.4: Σ_{q,k,v,out} size × (hp-1)/hp, over the hp axis."""
    if c.hp == 1:
        return 0.0
    q = out = 2.0 * c.s * c.d / c.sp * BYTES / 2         # Size(q) el=2SD/sp
    kv = kv_chunk_bytes(c)                               # K and V together
    vol = (q + out + kv) * (c.hp - 1) / c.hp
    bw = ICI if c.placement == "head_first" else ICI * MAJOR_PENALTY
    return vol / bw


def attention_op_time(c: AttnCase, *, backward: bool = False) -> float:
    """Paper's overlap model: T = T_a2a + (cp/w)·[A(w-1) + B]."""
    t_comp = comp_time_fwd(c) * (3.0 if backward else 1.0)
    t_inner = p2p_time(c, inner=True) * (2.0 if backward else 1.0)
    t_outer = p2p_time(c, inner=False) * (2.0 if backward else 1.0)
    w = min(c.w, c.cp)
    n_outer = c.cp // w
    a = max(t_comp, t_inner)
    b = max(t_comp, t_outer)
    ring = n_outer * (a * (w - 1) + b)
    return alltoall_time(c) * (2.0 if backward else 1.0) + ring


def layer_linear_flops(d: int, d_ff: int, s: int, h: int, hd: int,
                       h_kv: int) -> float:
    qkvo = 2.0 * s * d * (h * hd + 2 * h_kv * hd + h * hd)
    mlp = 2.0 * s * d * d_ff * 3
    return qkvo + mlp


def end_to_end_mfu(c: AttnCase, *, d_ff: int = 11008, n_layers: int = 32,
                   sc_pp: bool = True) -> float:
    """Modelled training MFU for a LLaMA-7B-like stack on sp devices.

    Non-attention compute is assumed perfectly overlapped/balanced (it has
    no sequence-length-dependent communication under hybrid ZeRO);
    attention uses the overlap model above.  Without SC++, the attention
    forward is recomputed during backward (full-layer gradient
    checkpointing); with SC++ it is not (the paper's §5.2 point).
    """
    lin_flops = layer_linear_flops(c.d, d_ff, c.s, c.h, c.hd, c.h_kv) / c.sp
    attn_flops = attn_flops_per_device(c)
    useful = (lin_flops + attn_flops) * 3.0       # fwd + 2×bwd
    t_lin = lin_flops * 3.0 / PEAK
    # full-layer remat recomputes the linear fwd either way (activation
    # memory at 1M tokens forces checkpointing; SC++ only spares attention)
    t_lin += lin_flops / PEAK
    t_attn = attention_op_time(c) + attention_op_time(c, backward=True)
    if not sc_pp:
        t_attn += attention_op_time(c)            # recompute fwd in bwd
    t_total = t_lin + t_attn
    return useful / (t_total * PEAK)
