"""Thin shim: the §4.5 cost model now lives in ``repro/analysis/cost.py``
(one implementation shared by the PlanTuner, the roofline, and these
benches).  This module re-exports the public surface so existing bench
invocations and notebooks keep working.
"""
from repro.analysis.cost import (                                 # noqa: F401
    BYTES, ICI, MAJOR_PENALTY, PEAK, AttnCase, CostConstants, V5E,
    alltoall_time, attention_op_time, attn_flops_per_device,
    comp_time_fwd, end_to_end_mfu, kv_chunk_bytes, layer_linear_flops,
    layer_step_time, p2p_time, train_step_time)
