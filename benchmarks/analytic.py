"""Deprecated shim: the §4.5 cost model lives in ``repro.analysis.cost``
(one implementation shared by the PlanTuner, the roofline, and the
benches — import it from there).  This module re-exports the public
surface for pre-PR-4 invocations and notebooks, and warns.
"""
import warnings

warnings.warn("benchmarks.analytic is deprecated; import from "
              "repro.analysis.cost instead", DeprecationWarning,
              stacklevel=2)

from repro.analysis.cost import (                        # noqa: E402,F401
    BYTES, ICI, MAJOR_PENALTY, PEAK, AttnCase, CostConstants, V5E,
    alltoall_time, attention_op_time, attn_flops_per_device,
    comp_time_fwd, end_to_end_mfu, kv_chunk_bytes, layer_linear_flops,
    layer_step_time, p2p_time, train_step_time)
