"""Benchmark harness — one section per paper table.

Prints ``name,us_per_call,derived`` CSV rows.

* t2/t3/t4/t5 mirror the paper's Tables 2-5 through the §4.5 cost model
  re-based on TPU v5e (repro/analysis/cost.py); ``us_per_call`` is the
  modelled per-op/step time, ``derived`` the headline metric (MFU, bytes,
  speedup).  The model's collective volumes are cross-checked against
  compiled dry-run HLO in EXPERIMENTS.md §Roofline.
* ``micro_*`` rows are real wall-clock measurements on this host (1 CPU
  device): ref-path attention, interpret-mode kernel check, reduced-config
  train steps.
* ``tune`` (also standalone: ``run.py tune``) exercises the PlanTuner
  end to end — calibrated enumerate+score, top-3 measured live — and
  writes the predicted-vs-measured record to ``BENCH_tune.json``.
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

from repro.analysis.cost import (AttnCase, alltoall_time, attention_op_time,
                                 end_to_end_mfu, kv_chunk_bytes)

SEQS = [131072, 262144, 524288, 1048576]


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


def t2_endtoend():
    """Table 2: LoongTrain grid vs DS-Ulysses (hp=sp) vs Megatron-CP
    (cp=sp) — 7B MHA & GQA on 32-way SP."""
    for h_kv, tag in ((32, "mha"), (8, "gqa")):
        for s in SEQS:
            rows = {}
            for hp in (1, 2, 4, 8, 16, 32):
                c = AttnCase(s=s, h_kv=h_kv, sp=32, hp=hp)
                rows[hp] = end_to_end_mfu(c)
            best_hp = max(rows, key=rows.get)
            _row(f"t2.{tag}.s{s}.ulysses", 0.0, f"mfu={rows[32]:.3f}")
            _row(f"t2.{tag}.s{s}.ringcp", 0.0, f"mfu={rows[1]:.3f}")
            _row(f"t2.{tag}.s{s}.loong_hp{best_hp}", 0.0,
                 f"mfu={rows[best_hp]:.3f};speedup_vs_ring="
                 f"{rows[best_hp]/max(rows[1],1e-9):.2f}x")


def t3_grid():
    """Table 3: hp×cp grid × placement × SC++ (64-way SP, 7B)."""
    for h_kv, tag in ((32, "mha"), (8, "gqa")):
        for s in (131072, 1048576):
            for hp in (1, 2, 4, 8, 16, 32):
                for placement in ("head_first", "context_first"):
                    for scpp in (True, False):
                        c = AttnCase(s=s, h_kv=h_kv, sp=64, hp=hp,
                                     placement=placement)
                        mfu = end_to_end_mfu(c, sc_pp=scpp)
                        _row(f"t3.{tag}.s{s}.hp{hp}cp{64//hp}."
                             f"{'hf' if placement=='head_first' else 'cf'}."
                             f"{'scpp' if scpp else 'base'}",
                             0.0, f"mfu={mfu:.3f}")


def t4_attention():
    """Table 4: single 2D-Attention op time + SeqAlltoAll volume."""
    for h_kv, tag in ((32, "mha"), (8, "gqa")):
        for s in (131072, 1048576):
            for hp in (1, 2, 4, 8, 16, 32):
                c = AttnCase(s=s, h_kv=h_kv, sp=64, hp=hp)
                t_op = attention_op_time(c) + attention_op_time(
                    c, backward=True)
                _row(f"t4.{tag}.s{s}.hp{hp}", t_op * 1e6,
                     f"a2a_bytes={alltoall_time(c)*50e9:.3e};"
                     f"kv_chunk={kv_chunk_bytes(c):.3e}")


def t5_double_ring():
    """Table 5: inner ring size sweep (cp=64 and cp=16)."""
    for cp, hp in ((64, 1), (16, 4)):
        for s in (131072, 1048576):
            base = None
            for w in (1, 2, 4, 8):
                c = AttnCase(s=s, h_kv=8, sp=64, hp=hp, w=w,
                             placement="context_first")
                t_op = attention_op_time(c)
                if base is None:
                    base = t_op
                _row(f"t5.gqa.s{s}.cp{cp}.w{w}", t_op * 1e6,
                     f"speedup_vs_w1={base/t_op:.2f}x")


def micro_ref_attention():
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    for (lq, h, d) in ((512, 8, 64), (1024, 8, 64)):
        q = jnp.asarray(rng.standard_normal((1, lq, h, d)), jnp.float32)
        f = jax.jit(lambda q: ops.flash_attention(q, q, q, causal=True,
                                                  impl="ref"))
        f(q).block_until_ready()
        t0 = time.perf_counter()
        n = 10
        for _ in range(n):
            f(q).block_until_ready()
        us = (time.perf_counter() - t0) / n * 1e6
        _row(f"micro.ref_attn.s{lq}", us, f"host_flops={4*lq*lq*h*d:.2e}")


def micro_kernel_interpret():
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 128, 4, 64)), jnp.float32)
    o_ref, _ = ref.attention_ref(q, q, q, causal=True)
    t0 = time.perf_counter()
    o_pal, _ = ops.flash_fwd_chunk(q, q, q, causal=True,
                                   impl="pallas_interpret",
                                   block_q=64, block_k=64)
    us = (time.perf_counter() - t0) * 1e6
    err = float(np.abs(np.asarray(o_pal) - np.asarray(o_ref)).max())
    _row("micro.pallas_interpret.s128", us, f"allclose_err={err:.2e}")


def micro_ring_step(out_path: str = "BENCH_ring.json"):
    """Micro wall-clock of one zigzag Double-Ring step (fwd + bwd) with a
    *traced* BandMask — flashref vs interpret-mode Pallas — written to
    ``BENCH_ring.json`` so the BENCH_* trajectory catches regressions on
    the ring hot path.  (Interpret mode emulates the kernel on CPU; its
    absolute time is interpreter overhead, not TPU time — the tracked
    signal is the trend of each impl against itself.)
    """
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.kernels.ref import BandMask

    rng = np.random.default_rng(0)
    b, s_loc, hq, hkv, d = 1, 256, 8, 2, 64
    c, cp = s_loc // 2, 4
    i_rank, j_visit = 2, 1           # a generic off-diagonal ring step
    q = jnp.asarray(rng.standard_normal((b, s_loc, hq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s_loc, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s_loc, hkv, d)), jnp.float32)

    bench = {"config": {"b": b, "s_loc": s_loc, "hq": hq, "hkv": hkv,
                        "d": d, "cp": cp, "step": [i_rank, j_visit],
                        "block": 64},
             "cases": []}
    for impl in ("flashref", "pallas_interpret"):
        fwd = jax.jit(lambda i, j: ops.flash_fwd_chunk(
            q, k, v, causal=True, band=BandMask.zigzag(i, j, c, cp),
            impl=impl, block_q=64, block_k=64))
        out, lse = fwd(jnp.int32(i_rank), jnp.int32(j_visit))
        jax.block_until_ready((out, lse))
        do = jnp.asarray(rng.standard_normal(out.shape), jnp.float32)
        bwd = jax.jit(lambda i, j: ops.flash_bwd_chunk(
            q, k, v, out, lse, do, causal=True,
            band=BandMask.zigzag(i, j, c, cp),
            impl=impl, block_q=64, block_k=64))
        jax.block_until_ready(bwd(jnp.int32(i_rank), jnp.int32(j_visit)))
        n = 5
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(fwd(jnp.int32(i_rank), jnp.int32(j_visit)))
        fwd_us = (time.perf_counter() - t0) / n * 1e6
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(bwd(jnp.int32(i_rank), jnp.int32(j_visit)))
        bwd_us = (time.perf_counter() - t0) / n * 1e6
        bench["cases"].append({"impl": impl, "fwd_us": round(fwd_us, 1),
                               "bwd_us": round(bwd_us, 1)})
        _row(f"micro.ring_step.{impl}.fwd", fwd_us, f"s_loc={s_loc}")
        _row(f"micro.ring_step.{impl}.bwd", bwd_us, f"s_loc={s_loc}")
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=2)


def micro_train_step():
    import jax
    import jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.core.plan import build_plan
    from repro.data.pipeline import SyntheticLM
    from repro.models.model import forward_loss, init_params

    for arch in ("qwen3-1.7b", "falcon-mamba-7b", "qwen3-moe-30b-a3b"):
        cfg = get_reduced(arch)
        plan = build_plan(cfg, devices=jax.devices()[:1], impl="ref",
                          seq_len=64, global_batch=4)
        rt = plan.rt
        params = init_params(cfg, jax.random.PRNGKey(0))
        data = SyntheticLM(plan.data_config(64, 4, zigzag=False), cfg)
        batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        with plan.mesh:
            g = jax.jit(jax.grad(
                lambda p: forward_loss(p, batch, rt, cfg)[0]))
            jax.block_until_ready(g(params))
            t0 = time.perf_counter()
            n = 3
            for _ in range(n):
                jax.block_until_ready(g(params))
        us = (time.perf_counter() - t0) / n * 1e6
        _row(f"micro.train_step.{arch}", us, "reduced-config grad step")


def bench_train_step(out_path: str = "BENCH_train_step.json"):
    """Gradient-accumulation sweep + sync-free-trainer-loop measurement,
    written to ``BENCH_train_step.json``.

    For ``grad_accum`` ∈ {1, 2, 4} at a fixed global batch, times the
    full jitted train step (fwd+bwd+AdamW) and derives steps/s.  For
    each, the driving loop is timed two ways: ``sync`` calls
    ``float(metrics["loss"])`` every step (the seed trainer's per-step
    device sync) and ``async`` only materializes at the end (the current
    trainer's ``log_every`` behaviour) — the gap is the dispatch
    pipelining recovered by keeping metrics on device.
    """
    import jax
    import jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.core.plan import build_plan
    from repro.data.pipeline import SyntheticLM
    from repro.models.model import init_params
    from repro.train.optimizer import init_opt_state
    from repro.train.train_step import jit_train_step

    cfg = get_reduced("qwen3-1.7b")
    gb, seq, n = 8, 64, 8
    bench = {"config": {"arch": cfg.name, "global_batch": gb,
                        "seq_len": seq, "steps": n}, "cases": []}
    for accum in (1, 2, 4):
        plan = build_plan(cfg, devices=jax.devices()[:1], impl="ref",
                          grad_accum=accum, seq_len=seq, global_batch=gb)
        data = SyntheticLM(plan.data_config(seq, gb), cfg)
        params = init_params(cfg, jax.random.PRNGKey(0))
        with plan.mesh:
            step, p_sh, o_sh = jit_train_step(plan, params, donate=False)
            opt = init_opt_state(params)
            batches = [{k: jnp.asarray(v) for k, v in data.batch(i).items()}
                       for i in range(n)]
            jax.block_until_ready(step(params, opt, batches[0]))

            def loop(sync: bool):
                p, o = params, opt
                t0 = time.perf_counter()
                for i in range(n):
                    p, o, m = step(p, o, batches[i])
                    if sync:
                        float(m["loss"])
                jax.block_until_ready((p, o))
                return n / (time.perf_counter() - t0)

            sps_sync, sps_async = loop(True), loop(False)
        bench["cases"].append({"grad_accum": accum,
                               "steps_per_s_sync": round(sps_sync, 3),
                               "steps_per_s_async": round(sps_async, 3)})
        _row(f"micro.accum{accum}.sync", 1e6 / sps_sync,
             f"steps_per_s={sps_sync:.2f}")
        _row(f"micro.accum{accum}.async", 1e6 / sps_async,
             f"steps_per_s={sps_async:.2f};"
             f"speedup={sps_async / sps_sync:.2f}x")
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=2)


def bench_serve(out_path: str = "BENCH_serve.json"):
    """Continuous-batching paged engine vs the fixed-batch contiguous
    baseline on uniform and mixed-length request streams, written to
    ``BENCH_serve.json``.

    Both schedulers run the same reduced model on this host with the same
    4 decode slots, their jitted steps compiled once (rep 0 of each
    stream warms, rep 1 is timed), and the **same KV-cache byte budget**
    (2048 token-slots): the fixed baseline spends it as 4 contiguous
    worst-case caches of 512, the paged engine as a shared 128-block
    pool.  The fixed baseline processes requests in submission-order
    groups: prompts padded to the per-stream max, decode runs until the
    *longest* request of the group finishes — the straggler effect the
    engine's in-place retirement removes.  Tokens/s counts only requested
    tokens; per-request latency is submit→finish (queueing included).
    """
    import jax
    import jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.core.plan import build_plan
    from repro.launch.serve import generate, make_generate_fns
    from repro.models.model import init_params
    from repro.serve import SamplingParams, ServeEngine

    cfg = get_reduced("qwen3-1.7b")
    plan = build_plan(cfg, devices=jax.devices()[:1], impl="ref")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rt = plan.rt
    b_slots = 4
    rng = np.random.default_rng(0)
    # (prompt_len, gen) per request; mixed spans ~32..512 total tokens
    # with bimodal gen lengths — the straggler case fixed batching pays for
    streams = {
        "uniform": [(64, 32)] * 8,
        "mixed": [(int(p), int(g)) for p, g in
                  zip(rng.integers(24, 385, size=8),
                      rng.choice([8, 16, 96, 128], size=8))],
    }
    max_total = max(p + g for reqs in streams.values() for p, g in reqs)
    prompts = {name: [rng.integers(0, cfg.vocab, size=p)
                      for p, _ in reqs]
               for name, reqs in streams.items()}

    bench = {"config": {"arch": cfg.name, "max_batch": b_slots,
                        "page_size": 16, "streams": streams},
             "cases": []}

    def pctl(lats, q):
        lats = sorted(lats)
        return lats[min(len(lats) - 1, int(len(lats) * q))]

    # -- paged engine: one jit set reused across streams.  Same pool bytes
    # (and slots) as the baseline's 4 × 512 contiguous caches, spent as a
    # shared 128-block pool — decode views follow the active lengths.
    from repro.serve.engine import EngineConfig
    assert max_total <= 512, max_total
    spec = EngineConfig(page_size=16, num_blocks=128,
                        max_blocks_per_seq=32, max_batch=b_slots,
                        prefill_chunk=128)
    with plan.mesh:
        eng = ServeEngine(plan, params, spec)
        eng.warmup(prompt_lens=(16, 32, 64, 128))   # compile all buckets
        for name, reqs in streams.items():
            for rep in range(2):       # rep 0 warms every (chunk, view) jit
                for (p_len, gen), p in zip(reqs, prompts[name]):
                    eng.submit(p, SamplingParams(), max_new_tokens=gen)
                res = eng.run()
                lats = [r["latency_s"] for r in res["requests"].values()]
            bench["cases"].append({
                "name": f"{name}.paged",
                "tokens_per_s": round(res["tokens_per_s"], 2),
                "p50_ms": round(pctl(lats, 0.5) * 1e3, 1),
                "p99_ms": round(pctl(lats, 0.99) * 1e3, 1),
                "generated": res["generated"],
                "wall_s": round(res["wall_s"], 3)})
            _row(f"serve.{name}.paged", res["wall_s"] * 1e6,
                 f"tok_s={res['tokens_per_s']:.1f}")

    # -- fixed-batch baseline: launch.serve.generate itself (token parity
    # with the engine pinned by tests/test_serve.py), with its jitted
    # steps hoisted once via make_generate_fns so repeated groups reuse
    # compiles.  Prompts pad to the *per-stream* max and each group
    # decodes to its own longest request — the baseline's honest best
    # schedule at fixed batching.
    fns = make_generate_fns(cfg, rt)

    def run_fixed(reqs, toks):
        s_pad = max(p for p, _ in reqs)
        t0 = time.perf_counter()
        lats, generated = [], 0
        for i in range(0, len(reqs), b_slots):
            group = reqs[i:i + b_slots]
            rows = toks[i:i + b_slots]
            tokens = np.zeros((b_slots, s_pad), np.int32)
            for j, r in enumerate(rows):
                tokens[j, :len(r)] = r
            out = generate(params, cfg, rt, jnp.asarray(tokens),
                           gen=max(g for _, g in group), fns=fns)
            jax.block_until_ready(out)
            t_group = time.perf_counter() - t0
            generated += sum(g for _, g in group)
            lats += [t_group] * len(group)     # group finishes together
        return generated, time.perf_counter() - t0, lats

    with plan.mesh:
        for name, reqs in streams.items():
            run_fixed(reqs, prompts[name])         # warm the jitted steps
            generated, wall, lats = run_fixed(reqs, prompts[name])
            tok_s = generated / max(wall, 1e-9)
            bench["cases"].append({
                "name": f"{name}.fixed",
                "tokens_per_s": round(tok_s, 2),
                "p50_ms": round(pctl(lats, 0.5) * 1e3, 1),
                "p99_ms": round(pctl(lats, 0.99) * 1e3, 1),
                "generated": generated,
                "wall_s": round(wall, 3)})
            _row(f"serve.{name}.fixed", wall * 1e6,
                 f"tok_s={tok_s:.1f}")

    by_name = {c["name"]: c for c in bench["cases"]}
    for name in streams:
        speed = (by_name[f"{name}.paged"]["tokens_per_s"]
                 / max(by_name[f"{name}.fixed"]["tokens_per_s"], 1e-9))
        bench["config"][f"{name}_paged_speedup"] = round(speed, 2)
        _row(f"serve.{name}.speedup", 0.0, f"paged_vs_fixed={speed:.2f}x")
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=2)


def bench_packed(out_path: str = "BENCH_packed.json"):
    """Masked-block skipping vs dense-masked packing on a mixed-length
    document stream, written to ``BENCH_packed.json``.

    A ``PackedLM`` stream (the real pipeline, mixed 64–320-token docs in
    a 1024 window) drives the doc-masked flash kernel twice: ``skip``
    (cross-document K blocks skipped via the doc-start predicate — the
    default) and ``dense`` (identical element-wise mask, skip disabled).
    Numerics are bitwise identical (pinned by tests); the tracked signal
    is the wall-clock of each mode plus the *deterministic* fraction of
    grid blocks each mode executes — the long-tail win of packing,
    measured rather than assumed.  (Interpret mode: absolute times are
    interpreter overhead; the trend of each mode against itself and the
    block fractions are the signal.)
    """
    import jax
    import jax.numpy as jnp
    from repro.data.pipeline import DataConfig, PackedLM
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    # MXU-sized blocks: the per-block matmul body dominates the
    # interpreter's fixed per-grid-step cost, so skipped blocks show up
    # in wall time, not just the block count.
    b, s, hq, hkv, d, blk = 1, 1024, 4, 2, 128, 128
    data = PackedLM(DataConfig(vocab=211, seq_len=s, global_batch=b,
                               cp=1, zigzag=False,
                               doc_len_range=(64, 320)))
    doc_np = np.asarray(data.batch(0)["doc_start"])
    doc = jnp.asarray(doc_np)
    q = jnp.asarray(rng.standard_normal((b, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)

    def exec_blocks(skip: bool):
        """Fraction of (q-block, k-block) grid steps the forward kernel
        runs (uniform causal band; doc table nondecreasing)."""
        runs = total = 0
        for q0 in range(0, s, blk):
            for k0 in range(0, s, blk):
                total += 1
                if k0 > q0 + blk - 1:               # causal block skip
                    continue
                if skip and k0 + blk - 1 < doc_np[0, q0]:
                    continue                        # cross-document skip
                runs += 1
        return runs / total

    n_docs = sum(len(ds) for ds in data.boundaries(0))
    bench = {"config": {"b": b, "s": s, "hq": hq, "hkv": hkv, "d": d,
                        "block": blk, "doc_len_range": [64, 320],
                        "n_docs": n_docs},
             "cases": []}
    # jit both modes up front, then interleave timed reps (skip, dense,
    # skip, ...) and take per-mode medians: host-load drift hits both
    # modes alike instead of whichever ran second.
    fns, times = {}, {}
    do = None
    for mode, skip in (("skip", True), ("dense", False)):
        kw = dict(causal=True, q_doc_start=doc, doc_skip=skip,
                  impl="pallas_interpret", block_q=blk, block_k=blk)
        fwd = jax.jit(lambda q, k, v, kw=kw: ops.flash_fwd_chunk(
            q, k, v, **kw))
        out, lse = fwd(q, k, v)
        jax.block_until_ready((out, lse))
        if do is None:
            do = jnp.asarray(rng.standard_normal(out.shape), jnp.float32)
        bwd = jax.jit(lambda q, k, v, out, lse, do, kw=kw:
                      ops.flash_bwd_chunk(q, k, v, out, lse, do, **kw))
        jax.block_until_ready(bwd(q, k, v, out, lse, do))
        fns[mode] = (fwd, bwd, out, lse)
        times[mode] = {"fwd": [], "bwd": []}
    for _ in range(5):
        for mode in ("skip", "dense"):
            fwd, bwd, out, lse = fns[mode]
            for tag, run in (("fwd", lambda: fwd(q, k, v)),
                             ("bwd", lambda: bwd(q, k, v, out, lse, do))):
                w0, c0 = time.perf_counter(), time.process_time()
                jax.block_until_ready(run())
                times[mode].setdefault(tag, []).append(
                    (time.perf_counter() - w0, time.process_time() - c0))
    for mode, skip in (("skip", True), ("dense", False)):
        case = {"mode": mode, "blocks_frac": round(exec_blocks(skip), 4)}
        for tag in ("fwd", "bwd"):
            wall, cpu = zip(*times[mode][tag])
            # cpu (process) time is the gated metric: on a loaded host it
            # tracks work done, where wall time tracks the scheduler
            case[f"{tag}_us"] = round(float(np.median(wall)) * 1e6, 1)
            case[f"{tag}_cpu_us"] = round(float(np.median(cpu)) * 1e6, 1)
        bench["cases"].append(case)
        _row(f"packed.{mode}.fwd", case["fwd_us"],
             f"cpu_us={case['fwd_cpu_us']};"
             f"blocks_frac={case['blocks_frac']}")
        _row(f"packed.{mode}.bwd", case["bwd_us"],
             f"cpu_us={case['bwd_cpu_us']};"
             f"blocks_frac={case['blocks_frac']}")
    by = {c["mode"]: c for c in bench["cases"]}
    for m in ("fwd_cpu_us", "bwd_cpu_us"):
        bench["config"][f"skip_speedup_{m[:3]}"] = round(
            by["dense"][m] / max(by["skip"][m], 1e-9), 2)
    bench["config"]["blocks_saved"] = round(
        1.0 - by["skip"]["blocks_frac"] / by["dense"]["blocks_frac"], 4)
    _row("packed.skip.speedup", 0.0,
         f"fwd={bench['config']['skip_speedup_fwd']}x;"
         f"bwd={bench['config']['skip_speedup_bwd']}x;"
         f"blocks_saved={bench['config']['blocks_saved']} (cpu-time)")
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=2)


def bench_tune(out_path: str = "BENCH_tune.json"):
    """PlanTuner predicted-vs-measured: enumerate+score the reduced
    config's plan space for this host's devices with *calibrated* cost
    constants, measure the analytic top-3 live (jit + timed steps), and
    write both numbers per candidate to ``BENCH_tune.json``.

    The tracked signal is the measured step time of the tuner's picks
    (does the winner stay fast?) — the prediction is recorded alongside
    as the model-quality trajectory (``ratio`` = measured/predicted; on
    this CPU host expect O(1–50): the analytic model is a TPU network
    model, calibration only rescales its peaks to host ballpark).
    """
    from repro.configs import get_reduced
    from repro.tune import tune
    from repro.tune.calibrate import constants_from_raw, run_microbenchmarks

    cfg = get_reduced("qwen3-1.7b")
    import jax
    const = constants_from_raw(run_microbenchmarks())   # hermetic: no file
    seq, gb = 256, 8
    result = tune(cfg, num_devices=len(jax.devices()), seq_len=seq,
                  global_batch=gb, memory_budget_gb=1.0, const=const,
                  measure_top_k=3, arch=cfg.name)
    bench = {"config": {"arch": cfg.name, "seq_len": seq,
                        "global_batch": gb,
                        "devices": len(jax.devices()),
                        "space_size": result.space_size,
                        "calibration": const.source},
             "cases": []}
    for s in result.ranked[:3]:
        case = {"tag": s.tag, "predicted_ms": round(s.score_s * 1e3, 3)}
        if s.measured_s is not None:
            case["measured_ms"] = round(s.measured_s * 1e3, 3)
            case["ratio"] = round(s.measured_s / max(s.score_s, 1e-12), 2)
        bench["cases"].append(case)
        _row(f"tune.{s.tag}", (s.measured_s or s.score_s) * 1e6,
             f"predicted_ms={case['predicted_ms']}")
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=2)


def _ckpt_worker():
    """Subprocess body for ``bench_ckpt`` (needs 8 fake devices, so it
    cannot run in the caller's process — the device count locks at first
    jax use).  Prints one JSON object on the last stdout line."""
    import shutil
    import tempfile

    import jax
    from repro.configs import get_reduced
    from repro.core.plan import build_plan
    from repro.core.topology import ParallelConfig
    from repro.models.model import init_params
    from repro.runtime.checkpoint import CheckpointManager
    from repro.train.optimizer import init_opt_state

    cfg = get_reduced("qwen3-1.7b")
    grids = [("replica.x1", ParallelConfig(dp=2), "replica"),
             ("zero_dp.x2", ParallelConfig(dp=2), "dp"),
             ("zero_dp_sp.x8",
              ParallelConfig(dp=2, hp=2, cp_outer=1, cp_inner=2), "dp_sp")]
    cases = []
    for tag, pc, zero in grids:
        plan = build_plan(cfg, pc, devices=jax.devices()[:pc.num_devices],
                          impl="ref", seq_len=64, global_batch=8,
                          zero=zero)
        with plan.mesh:
            params = init_params(cfg, jax.random.PRNGKey(0))
            p_sh = plan.param_shardings(params)
            params = jax.device_put(params, p_sh)
            opt = jax.device_put(init_opt_state(params),
                                 plan.opt_shardings(p_sh))
        state = {"params": params, "opt": opt}
        d = tempfile.mkdtemp(prefix=f"bench_ckpt_{zero}_")
        try:
            mgr = CheckpointManager(d, plan=plan, keep=2)
            stalls, writes, saves, resumes = [], [], [], []
            for rep in range(3):
                t0 = time.perf_counter()
                mgr.save_async(state, 2 * rep + 1)
                stalls.append(time.perf_counter() - t0)  # snapshot only
                t0 = time.perf_counter()
                mgr.flush()
                writes.append(time.perf_counter() - t0)
                t0 = time.perf_counter()
                mgr.save(state, 2 * rep + 2)
                saves.append(time.perf_counter() - t0)
                t0 = time.perf_counter()
                _, step = mgr.restore(state)
                resumes.append(time.perf_counter() - t0)
                assert step == 2 * rep + 2
            man = mgr.manifest()
            cases.append({
                "tag": tag, "zero_extent": plan.mem["zero_extent"],
                "bytes_per_host": man["bytes_per_host"],
                "max_shards": max(e["shards"] for e in man["leaves"]),
                "stall_ms": round(float(np.median(stalls)) * 1e3, 2),
                "write_ms": round(float(np.median(writes)) * 1e3, 2),
                "save_ms": round(float(np.median(saves)) * 1e3, 2),
                "resume_ms": round(float(np.median(resumes)) * 1e3, 2),
                "model_bytes_per_host": int(plan.mem["ckpt_bytes_host"]),
            })
        finally:
            shutil.rmtree(d, ignore_errors=True)
    print(json.dumps({"cases": cases}))


def bench_ckpt(out_path: str = "BENCH_ckpt.json"):
    """Plan-aware sharded checkpointing across ZeRO extents, written to
    ``BENCH_ckpt.json``.

    One worker subprocess (8 fake devices) saves+restores the same
    reduced train state under extents 1 (replica), 2 (ZeRO over dp=2)
    and 8 (dp·sp) and reports, per extent: the ``save_async`` **stall**
    (the device→host snapshot — the only part that blocks the step
    loop), the background write time, the blocking-save and
    time-to-resume wall times, and the manifest's ``bytes_per_host``.
    The layout claim under test: per-host checkpoint bytes shrink with
    the ZeRO extent (each host serializes only its shards), so the
    recorded ``bytes_shrink_with_extent`` must stay true.
    """
    import os
    import subprocess

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root,
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    res = subprocess.run([sys.executable, os.path.abspath(__file__),
                          "_ckpt_worker"], capture_output=True, text=True,
                         timeout=900, env=env)
    assert res.returncode == 0, res.stderr[-2000:]
    data = json.loads(res.stdout.strip().splitlines()[-1])
    bench = {"config": {"arch": "qwen3-1.7b", "seq_len": 64,
                        "global_batch": 8, "devices": 8,
                        "state": "params + opt (m, v, step)"},
             "cases": data["cases"]}
    by_extent = sorted(data["cases"], key=lambda c: c["zero_extent"])
    bench["config"]["bytes_shrink_with_extent"] = all(
        a["bytes_per_host"] > b["bytes_per_host"]
        for a, b in zip(by_extent, by_extent[1:]))
    for c in data["cases"]:
        _row(f"ckpt.{c['tag']}.stall", c["stall_ms"] * 1e3,
             f"bytes_per_host={c['bytes_per_host']};"
             f"extent={c['zero_extent']};shards={c['max_shards']}")
        _row(f"ckpt.{c['tag']}.resume", c["resume_ms"] * 1e3,
             f"save_ms={c['save_ms']};write_ms={c['write_ms']}")
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=2)


def _offload_worker():
    """Subprocess body for ``bench_offload`` (needs 8 fake devices for the
    combined hp×cp grid).  Prints one JSON object on the last stdout line."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.core.attention2d import (Attn2DConfig, attention_2d,
                                        chunked_attention_2d)
    from repro.core.plan import plan_memory
    from repro.core.topology import ParallelConfig, make_mesh
    from repro.core.zigzag import from_zigzag, to_zigzag
    from repro.runtime.offload import OffloadManager

    cases = []

    # -- memory model: longest trainable sequence at a fixed HBM budget.
    # Deterministic (no wall clock anywhere): the chunk pipeline keeps
    # only the active+prefetched fraction 2/C of the sequence-extensive
    # bytes resident, so depth C buys exactly C/2× sequence once C >= 2.
    cfg = get_reduced("qwen3-1.7b")
    pc = ParallelConfig(dp=1, hp=2, cp_outer=2, cp_inner=2)
    budget_gb = 0.05
    base = None
    for chunks in (1, 4, 8, 16):
        _, _, _, mem = plan_memory(cfg, pc, remat="none",
                                   memory_budget_gb=budget_gb,
                                   seq_len=131072, global_batch=8,
                                   offload_chunks=chunks)
        ms = mem["max_seq_at_budget"]
        if base is None:
            base = ms
        cases.append({
            "kind": "max_seq", "tag": f"max_seq.off{chunks}",
            "chunks": chunks, "max_seq_at_budget": int(ms),
            "seq_ratio": round(ms / max(base, 1), 2),
            "act_dev_bytes": int(mem["act_dev"]),
            "act_host_bytes": int(mem["act_host"]),
            "wire_ms": round(mem["offload_wire_s"] * 1e3, 3)})

    # -- measured: chunked pipeline vs resident double-ring, same grid
    acfg = Attn2DConfig(hp=pc.hp, n_out=pc.cp_outer, w=pc.cp_inner,
                        causal=True, impl="ref")
    mesh = make_mesh(pc)
    cp = pc.cp
    rng = np.random.default_rng(0)
    B, S, H, HKV, D = 1, 512, 4, 2, 16
    chunks = 4
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, HKV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, HKV, D)), jnp.float32)
    do = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    qkv_bytes = sum(int(np.asarray(x).nbytes) for x in (q, k, v))

    def resident_loss(q, k, v):
        qz, kz, vz = (to_zigzag(x, cp) for x in (q, k, v))
        out = attention_2d(qz, kz, vz, mesh=mesh, cfg=acfg)
        return (from_zigzag(out, cp) * do).sum()

    res_grad = jax.jit(jax.value_and_grad(resident_loss, argnums=(0, 1, 2)))

    def run_resident():
        with mesh:
            return jax.block_until_ready(res_grad(q, k, v))

    def run_chunked(mgr):
        with mesh:
            out, vjp = chunked_attention_2d(q, k, v, mesh=mesh, cfg=acfg,
                                            chunks=chunks, offload=mgr)
            return jax.block_until_ready((out, vjp(do)))

    run_resident()                       # compile warm-up
    run_chunked(OffloadManager())
    times = {"resident": [], "chunked": []}
    stats = None
    for _ in range(5):
        t0, c0 = time.perf_counter(), time.process_time()
        run_resident()
        times["resident"].append((time.perf_counter() - t0,
                                  time.process_time() - c0))
        mgr = OffloadManager()
        t0, c0 = time.perf_counter(), time.process_time()
        run_chunked(mgr)
        times["chunked"].append((time.perf_counter() - t0,
                                 time.process_time() - c0))
        stats = mgr.stats()

    med = {}
    for mode in ("resident", "chunked"):
        wall, cpu = zip(*times[mode])
        med[mode] = {"wall_us": round(float(np.median(wall)) * 1e6, 1),
                     "cpu_us": round(float(np.median(cpu)) * 1e6, 1)}
    cases.append(dict(kind="step", tag="step.resident", mode="resident",
                      **med["resident"]))
    cases.append(dict(
        kind="step", tag=f"step.chunked.off{chunks}", mode="chunked",
        chunks=chunks, **med["chunked"],
        overhead=round(med["chunked"]["wall_us"]
                       / max(med["resident"]["wall_us"], 1e-9), 2),
        stalls=int(stats["stalls"]),
        peak_device_bytes=int(stats["peak_device_bytes"]),
        peak_device_frac=round(stats["peak_device_bytes"]
                               / max(qkv_bytes, 1), 3),
        h2d_bytes=int(stats["h2d_bytes"]),
        d2h_bytes=int(stats["d2h_bytes"])))
    print(json.dumps({"cases": cases}))


def bench_offload(out_path: str = "BENCH_offload.json"):
    """FPDT sequence-chunk pipelining with host KV offload, written to
    ``BENCH_offload.json``.

    One worker subprocess (8 fake devices) records the two sides of the
    offload trade:

    * **max trainable sequence** at a fixed HBM budget, straight from the
      plan memory model at depths 1/4/8/16 — deterministic, so the gate
      allows no noise; the resident fraction is ``2/C`` (active + next
      chunk), so depth 8 must buy ≥ 4× sequence over the resident
      baseline (``seq_gain_4x_at_off8``).
    * **step overhead**: measured fwd+bwd wall time of the chunked
      pipeline (depth 4) against the resident double-ring on the same
      combined hp=2 × cp=2x2 grid, with the ``OffloadManager``
      telemetry — ``stalls`` must stay 0 (every chunk's H2D copy lands
      before the pipeline reads it) and ``peak_device_frac`` records the
      HBM residency actually held.
    """
    import os
    import subprocess

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root,
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    res = subprocess.run([sys.executable, os.path.abspath(__file__),
                          "_offload_worker"], capture_output=True,
                         text=True, timeout=900, env=env)
    assert res.returncode == 0, res.stderr[-2000:]
    data = json.loads(res.stdout.strip().splitlines()[-1])
    by = {c["tag"]: c for c in data["cases"]}
    bench = {"config": {"arch": "qwen3-1.7b", "budget_gb": 0.05,
                        "plan_seq_len": 131072, "devices": 8,
                        "grid": "dp1.hp2.cp2x2",
                        "seq_gain_4x_at_off8":
                            by["max_seq.off8"]["seq_ratio"] >= 4.0,
                        "pipeline_stalls":
                            by["step.chunked.off4"]["stalls"]},
             "cases": data["cases"]}
    for c in data["cases"]:
        if c["kind"] == "max_seq":
            _row(f"offload.{c['tag']}", 0.0,
                 f"max_seq={c['max_seq_at_budget']};"
                 f"ratio={c['seq_ratio']}x;wire_ms={c['wire_ms']}")
        elif c["mode"] == "resident":
            _row("offload.step.resident", c["wall_us"],
                 f"cpu_us={c['cpu_us']}")
        else:
            _row(f"offload.{c['tag']}", c["wall_us"],
                 f"overhead={c['overhead']}x;stalls={c['stalls']};"
                 f"peak_dev_frac={c['peak_device_frac']}")
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=2)


def main() -> None:
    sections = {"ring": micro_ring_step, "train": bench_train_step,
                "serve": bench_serve, "tune": bench_tune,
                "packed": bench_packed, "ckpt": bench_ckpt,
                "offload": bench_offload}
    if len(sys.argv) > 1 and sys.argv[1] == "_ckpt_worker":
        _ckpt_worker()
        return
    if len(sys.argv) > 1 and sys.argv[1] == "_offload_worker":
        _offload_worker()
        return
    if len(sys.argv) > 1 and sys.argv[1] in sections:
        print("name,us_per_call,derived")
        sections[sys.argv[1]]()
        return
    print("name,us_per_call,derived")
    t2_endtoend()
    t3_grid()
    t4_attention()
    t5_double_ring()
    micro_ref_attention()
    micro_kernel_interpret()
    micro_ring_step()
    micro_train_step()
    bench_train_step()
    bench_serve()
    bench_tune()
    bench_packed()
    bench_ckpt()
    bench_offload()


if __name__ == "__main__":
    main()
