"""Benchmark harness — one section per paper table.

Prints ``name,us_per_call,derived`` CSV rows.

* t2/t3/t4/t5 mirror the paper's Tables 2-5 through the §4.5 cost model
  re-based on TPU v5e (benchmarks/analytic.py); ``us_per_call`` is the
  modelled per-op/step time, ``derived`` the headline metric (MFU, bytes,
  speedup).  The model's collective volumes are cross-checked against
  compiled dry-run HLO in EXPERIMENTS.md §Roofline.
* ``micro_*`` rows are real wall-clock measurements on this host (1 CPU
  device): ref-path attention, interpret-mode kernel check, reduced-config
  train steps.
* ``tune`` (also standalone: ``run.py tune``) exercises the PlanTuner
  end to end — calibrated enumerate+score, top-3 measured live — and
  writes the predicted-vs-measured record to ``BENCH_tune.json``.
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

from benchmarks.analytic import (AttnCase, alltoall_time, attention_op_time,
                                 end_to_end_mfu, kv_chunk_bytes)

SEQS = [131072, 262144, 524288, 1048576]


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


def t2_endtoend():
    """Table 2: LoongTrain grid vs DS-Ulysses (hp=sp) vs Megatron-CP
    (cp=sp) — 7B MHA & GQA on 32-way SP."""
    for h_kv, tag in ((32, "mha"), (8, "gqa")):
        for s in SEQS:
            rows = {}
            for hp in (1, 2, 4, 8, 16, 32):
                c = AttnCase(s=s, h_kv=h_kv, sp=32, hp=hp)
                rows[hp] = end_to_end_mfu(c)
            best_hp = max(rows, key=rows.get)
            _row(f"t2.{tag}.s{s}.ulysses", 0.0, f"mfu={rows[32]:.3f}")
            _row(f"t2.{tag}.s{s}.ringcp", 0.0, f"mfu={rows[1]:.3f}")
            _row(f"t2.{tag}.s{s}.loong_hp{best_hp}", 0.0,
                 f"mfu={rows[best_hp]:.3f};speedup_vs_ring="
                 f"{rows[best_hp]/max(rows[1],1e-9):.2f}x")


def t3_grid():
    """Table 3: hp×cp grid × placement × SC++ (64-way SP, 7B)."""
    for h_kv, tag in ((32, "mha"), (8, "gqa")):
        for s in (131072, 1048576):
            for hp in (1, 2, 4, 8, 16, 32):
                for placement in ("head_first", "context_first"):
                    for scpp in (True, False):
                        c = AttnCase(s=s, h_kv=h_kv, sp=64, hp=hp,
                                     placement=placement)
                        mfu = end_to_end_mfu(c, sc_pp=scpp)
                        _row(f"t3.{tag}.s{s}.hp{hp}cp{64//hp}."
                             f"{'hf' if placement=='head_first' else 'cf'}."
                             f"{'scpp' if scpp else 'base'}",
                             0.0, f"mfu={mfu:.3f}")


def t4_attention():
    """Table 4: single 2D-Attention op time + SeqAlltoAll volume."""
    for h_kv, tag in ((32, "mha"), (8, "gqa")):
        for s in (131072, 1048576):
            for hp in (1, 2, 4, 8, 16, 32):
                c = AttnCase(s=s, h_kv=h_kv, sp=64, hp=hp)
                t_op = attention_op_time(c) + attention_op_time(
                    c, backward=True)
                _row(f"t4.{tag}.s{s}.hp{hp}", t_op * 1e6,
                     f"a2a_bytes={alltoall_time(c)*50e9:.3e};"
                     f"kv_chunk={kv_chunk_bytes(c):.3e}")


def t5_double_ring():
    """Table 5: inner ring size sweep (cp=64 and cp=16)."""
    for cp, hp in ((64, 1), (16, 4)):
        for s in (131072, 1048576):
            base = None
            for w in (1, 2, 4, 8):
                c = AttnCase(s=s, h_kv=8, sp=64, hp=hp, w=w,
                             placement="context_first")
                t_op = attention_op_time(c)
                if base is None:
                    base = t_op
                _row(f"t5.gqa.s{s}.cp{cp}.w{w}", t_op * 1e6,
                     f"speedup_vs_w1={base/t_op:.2f}x")


def micro_ref_attention():
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    for (lq, h, d) in ((512, 8, 64), (1024, 8, 64)):
        q = jnp.asarray(rng.standard_normal((1, lq, h, d)), jnp.float32)
        f = jax.jit(lambda q: ops.flash_attention(q, q, q, causal=True,
                                                  impl="ref"))
        f(q).block_until_ready()
        t0 = time.perf_counter()
        n = 10
        for _ in range(n):
            f(q).block_until_ready()
        us = (time.perf_counter() - t0) / n * 1e6
        _row(f"micro.ref_attn.s{lq}", us, f"host_flops={4*lq*lq*h*d:.2e}")


def micro_kernel_interpret():
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 128, 4, 64)), jnp.float32)
    o_ref, _ = ref.attention_ref(q, q, q, causal=True)
    t0 = time.perf_counter()
    o_pal, _ = ops.flash_fwd_chunk(q, q, q, causal=True,
                                   impl="pallas_interpret",
                                   block_q=64, block_k=64)
    us = (time.perf_counter() - t0) * 1e6
    err = float(np.abs(np.asarray(o_pal) - np.asarray(o_ref)).max())
    _row("micro.pallas_interpret.s128", us, f"allclose_err={err:.2e}")


def micro_ring_step(out_path: str = "BENCH_ring.json"):
    """Micro wall-clock of one zigzag Double-Ring step (fwd + bwd) with a
    *traced* BandMask — flashref vs interpret-mode Pallas — written to
    ``BENCH_ring.json`` so the BENCH_* trajectory catches regressions on
    the ring hot path.  (Interpret mode emulates the kernel on CPU; its
    absolute time is interpreter overhead, not TPU time — the tracked
    signal is the trend of each impl against itself.)
    """
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.kernels.ref import BandMask

    rng = np.random.default_rng(0)
    b, s_loc, hq, hkv, d = 1, 256, 8, 2, 64
    c, cp = s_loc // 2, 4
    i_rank, j_visit = 2, 1           # a generic off-diagonal ring step
    q = jnp.asarray(rng.standard_normal((b, s_loc, hq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s_loc, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s_loc, hkv, d)), jnp.float32)

    bench = {"config": {"b": b, "s_loc": s_loc, "hq": hq, "hkv": hkv,
                        "d": d, "cp": cp, "step": [i_rank, j_visit],
                        "block": 64},
             "cases": []}
    for impl in ("flashref", "pallas_interpret"):
        fwd = jax.jit(lambda i, j: ops.flash_fwd_chunk(
            q, k, v, causal=True, band=BandMask.zigzag(i, j, c, cp),
            impl=impl, block_q=64, block_k=64))
        out, lse = fwd(jnp.int32(i_rank), jnp.int32(j_visit))
        jax.block_until_ready((out, lse))
        do = jnp.asarray(rng.standard_normal(out.shape), jnp.float32)
        bwd = jax.jit(lambda i, j: ops.flash_bwd_chunk(
            q, k, v, out, lse, do, causal=True,
            band=BandMask.zigzag(i, j, c, cp),
            impl=impl, block_q=64, block_k=64))
        jax.block_until_ready(bwd(jnp.int32(i_rank), jnp.int32(j_visit)))
        n = 5
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(fwd(jnp.int32(i_rank), jnp.int32(j_visit)))
        fwd_us = (time.perf_counter() - t0) / n * 1e6
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(bwd(jnp.int32(i_rank), jnp.int32(j_visit)))
        bwd_us = (time.perf_counter() - t0) / n * 1e6
        bench["cases"].append({"impl": impl, "fwd_us": round(fwd_us, 1),
                               "bwd_us": round(bwd_us, 1)})
        _row(f"micro.ring_step.{impl}.fwd", fwd_us, f"s_loc={s_loc}")
        _row(f"micro.ring_step.{impl}.bwd", bwd_us, f"s_loc={s_loc}")
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=2)


def micro_train_step():
    import jax
    import jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.core.plan import build_plan
    from repro.data.pipeline import SyntheticLM
    from repro.models.model import forward_loss, init_params

    for arch in ("qwen3-1.7b", "falcon-mamba-7b", "qwen3-moe-30b-a3b"):
        cfg = get_reduced(arch)
        plan = build_plan(cfg, devices=jax.devices()[:1], impl="ref",
                          seq_len=64, global_batch=4)
        rt = plan.rt
        params = init_params(cfg, jax.random.PRNGKey(0))
        data = SyntheticLM(plan.data_config(64, 4, zigzag=False), cfg)
        batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        with plan.mesh:
            g = jax.jit(jax.grad(
                lambda p: forward_loss(p, batch, rt, cfg)[0]))
            jax.block_until_ready(g(params))
            t0 = time.perf_counter()
            n = 3
            for _ in range(n):
                jax.block_until_ready(g(params))
        us = (time.perf_counter() - t0) / n * 1e6
        _row(f"micro.train_step.{arch}", us, "reduced-config grad step")


def bench_train_step(out_path: str = "BENCH_train_step.json"):
    """Gradient-accumulation sweep + sync-free-trainer-loop measurement,
    written to ``BENCH_train_step.json``.

    For ``grad_accum`` ∈ {1, 2, 4} at a fixed global batch, times the
    full jitted train step (fwd+bwd+AdamW) and derives steps/s.  For
    each, the driving loop is timed two ways: ``sync`` calls
    ``float(metrics["loss"])`` every step (the seed trainer's per-step
    device sync) and ``async`` only materializes at the end (the current
    trainer's ``log_every`` behaviour) — the gap is the dispatch
    pipelining recovered by keeping metrics on device.
    """
    import jax
    import jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.core.plan import build_plan
    from repro.data.pipeline import SyntheticLM
    from repro.models.model import init_params
    from repro.train.optimizer import init_opt_state
    from repro.train.train_step import jit_train_step

    cfg = get_reduced("qwen3-1.7b")
    gb, seq, n = 8, 64, 8
    bench = {"config": {"arch": cfg.name, "global_batch": gb,
                        "seq_len": seq, "steps": n}, "cases": []}
    for accum in (1, 2, 4):
        plan = build_plan(cfg, devices=jax.devices()[:1], impl="ref",
                          grad_accum=accum, seq_len=seq, global_batch=gb)
        data = SyntheticLM(plan.data_config(seq, gb), cfg)
        params = init_params(cfg, jax.random.PRNGKey(0))
        with plan.mesh:
            step, p_sh, o_sh = jit_train_step(plan, params, donate=False)
            opt = init_opt_state(params)
            batches = [{k: jnp.asarray(v) for k, v in data.batch(i).items()}
                       for i in range(n)]
            jax.block_until_ready(step(params, opt, batches[0]))

            def loop(sync: bool):
                p, o = params, opt
                t0 = time.perf_counter()
                for i in range(n):
                    p, o, m = step(p, o, batches[i])
                    if sync:
                        float(m["loss"])
                jax.block_until_ready((p, o))
                return n / (time.perf_counter() - t0)

            sps_sync, sps_async = loop(True), loop(False)
        bench["cases"].append({"grad_accum": accum,
                               "steps_per_s_sync": round(sps_sync, 3),
                               "steps_per_s_async": round(sps_async, 3)})
        _row(f"micro.accum{accum}.sync", 1e6 / sps_sync,
             f"steps_per_s={sps_sync:.2f}")
        _row(f"micro.accum{accum}.async", 1e6 / sps_async,
             f"steps_per_s={sps_async:.2f};"
             f"speedup={sps_async / sps_sync:.2f}x")
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=2)


def bench_serve(out_path: str = "BENCH_serve.json"):
    """Continuous-batching paged engine vs the fixed-batch contiguous
    baseline on uniform and mixed-length request streams, written to
    ``BENCH_serve.json``.

    Both schedulers run the same reduced model on this host with the same
    4 decode slots, their jitted steps compiled once (rep 0 of each
    stream warms, rep 1 is timed), and the **same KV-cache byte budget**
    (2048 token-slots): the fixed baseline spends it as 4 contiguous
    worst-case caches of 512, the paged engine as a shared 128-block
    pool.  The fixed baseline processes requests in submission-order
    groups: prompts padded to the per-stream max, decode runs until the
    *longest* request of the group finishes — the straggler effect the
    engine's in-place retirement removes.  Tokens/s counts only requested
    tokens; per-request latency is submit→finish (queueing included).
    """
    import jax
    import jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.core.plan import build_plan
    from repro.launch.serve import generate, make_generate_fns
    from repro.models.model import init_params
    from repro.serve import SamplingParams, ServeEngine

    cfg = get_reduced("qwen3-1.7b")
    plan = build_plan(cfg, devices=jax.devices()[:1], impl="ref")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rt = plan.rt
    b_slots = 4
    rng = np.random.default_rng(0)
    # (prompt_len, gen) per request; mixed spans ~32..512 total tokens
    # with bimodal gen lengths — the straggler case fixed batching pays for
    streams = {
        "uniform": [(64, 32)] * 8,
        "mixed": [(int(p), int(g)) for p, g in
                  zip(rng.integers(24, 385, size=8),
                      rng.choice([8, 16, 96, 128], size=8))],
    }
    max_total = max(p + g for reqs in streams.values() for p, g in reqs)
    prompts = {name: [rng.integers(0, cfg.vocab, size=p)
                      for p, _ in reqs]
               for name, reqs in streams.items()}

    bench = {"config": {"arch": cfg.name, "max_batch": b_slots,
                        "page_size": 16, "streams": streams},
             "cases": []}

    def pctl(lats, q):
        lats = sorted(lats)
        return lats[min(len(lats) - 1, int(len(lats) * q))]

    # -- paged engine: one jit set reused across streams.  Same pool bytes
    # (and slots) as the baseline's 4 × 512 contiguous caches, spent as a
    # shared 128-block pool — decode views follow the active lengths.
    from repro.serve.engine import EngineConfig
    assert max_total <= 512, max_total
    spec = EngineConfig(page_size=16, num_blocks=128,
                        max_blocks_per_seq=32, max_batch=b_slots,
                        prefill_chunk=128)
    with plan.mesh:
        eng = ServeEngine(plan, params, spec)
        eng.warmup(prompt_lens=(16, 32, 64, 128))   # compile all buckets
        for name, reqs in streams.items():
            for rep in range(2):       # rep 0 warms every (chunk, view) jit
                for (p_len, gen), p in zip(reqs, prompts[name]):
                    eng.submit(p, SamplingParams(), max_new_tokens=gen)
                res = eng.run()
                lats = [r["latency_s"] for r in res["requests"].values()]
            bench["cases"].append({
                "name": f"{name}.paged",
                "tokens_per_s": round(res["tokens_per_s"], 2),
                "p50_ms": round(pctl(lats, 0.5) * 1e3, 1),
                "p99_ms": round(pctl(lats, 0.99) * 1e3, 1),
                "generated": res["generated"],
                "wall_s": round(res["wall_s"], 3)})
            _row(f"serve.{name}.paged", res["wall_s"] * 1e6,
                 f"tok_s={res['tokens_per_s']:.1f}")

    # -- fixed-batch baseline: launch.serve.generate itself (token parity
    # with the engine pinned by tests/test_serve.py), with its jitted
    # steps hoisted once via make_generate_fns so repeated groups reuse
    # compiles.  Prompts pad to the *per-stream* max and each group
    # decodes to its own longest request — the baseline's honest best
    # schedule at fixed batching.
    fns = make_generate_fns(cfg, rt)

    def run_fixed(reqs, toks):
        s_pad = max(p for p, _ in reqs)
        t0 = time.perf_counter()
        lats, generated = [], 0
        for i in range(0, len(reqs), b_slots):
            group = reqs[i:i + b_slots]
            rows = toks[i:i + b_slots]
            tokens = np.zeros((b_slots, s_pad), np.int32)
            for j, r in enumerate(rows):
                tokens[j, :len(r)] = r
            out = generate(params, cfg, rt, jnp.asarray(tokens),
                           gen=max(g for _, g in group), fns=fns)
            jax.block_until_ready(out)
            t_group = time.perf_counter() - t0
            generated += sum(g for _, g in group)
            lats += [t_group] * len(group)     # group finishes together
        return generated, time.perf_counter() - t0, lats

    with plan.mesh:
        for name, reqs in streams.items():
            run_fixed(reqs, prompts[name])         # warm the jitted steps
            generated, wall, lats = run_fixed(reqs, prompts[name])
            tok_s = generated / max(wall, 1e-9)
            bench["cases"].append({
                "name": f"{name}.fixed",
                "tokens_per_s": round(tok_s, 2),
                "p50_ms": round(pctl(lats, 0.5) * 1e3, 1),
                "p99_ms": round(pctl(lats, 0.99) * 1e3, 1),
                "generated": generated,
                "wall_s": round(wall, 3)})
            _row(f"serve.{name}.fixed", wall * 1e6,
                 f"tok_s={tok_s:.1f}")

    by_name = {c["name"]: c for c in bench["cases"]}
    for name in streams:
        speed = (by_name[f"{name}.paged"]["tokens_per_s"]
                 / max(by_name[f"{name}.fixed"]["tokens_per_s"], 1e-9))
        bench["config"][f"{name}_paged_speedup"] = round(speed, 2)
        _row(f"serve.{name}.speedup", 0.0, f"paged_vs_fixed={speed:.2f}x")
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=2)


def bench_tune(out_path: str = "BENCH_tune.json"):
    """PlanTuner predicted-vs-measured: enumerate+score the reduced
    config's plan space for this host's devices with *calibrated* cost
    constants, measure the analytic top-3 live (jit + timed steps), and
    write both numbers per candidate to ``BENCH_tune.json``.

    The tracked signal is the measured step time of the tuner's picks
    (does the winner stay fast?) — the prediction is recorded alongside
    as the model-quality trajectory (``ratio`` = measured/predicted; on
    this CPU host expect O(1–50): the analytic model is a TPU network
    model, calibration only rescales its peaks to host ballpark).
    """
    from repro.configs import get_reduced
    from repro.tune import tune
    from repro.tune.calibrate import constants_from_raw, run_microbenchmarks

    cfg = get_reduced("qwen3-1.7b")
    import jax
    const = constants_from_raw(run_microbenchmarks())   # hermetic: no file
    seq, gb = 256, 8
    result = tune(cfg, num_devices=len(jax.devices()), seq_len=seq,
                  global_batch=gb, memory_budget_gb=1.0, const=const,
                  measure_top_k=3, arch=cfg.name)
    bench = {"config": {"arch": cfg.name, "seq_len": seq,
                        "global_batch": gb,
                        "devices": len(jax.devices()),
                        "space_size": result.space_size,
                        "calibration": const.source},
             "cases": []}
    for s in result.ranked[:3]:
        case = {"tag": s.tag, "predicted_ms": round(s.score_s * 1e3, 3)}
        if s.measured_s is not None:
            case["measured_ms"] = round(s.measured_s * 1e3, 3)
            case["ratio"] = round(s.measured_s / max(s.score_s, 1e-12), 2)
        bench["cases"].append(case)
        _row(f"tune.{s.tag}", (s.measured_s or s.score_s) * 1e6,
             f"predicted_ms={case['predicted_ms']}")
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=2)


def main() -> None:
    sections = {"ring": micro_ring_step, "train": bench_train_step,
                "serve": bench_serve, "tune": bench_tune}
    if len(sys.argv) > 1 and sys.argv[1] in sections:
        print("name,us_per_call,derived")
        sections[sys.argv[1]]()
        return
    print("name,us_per_call,derived")
    t2_endtoend()
    t3_grid()
    t4_attention()
    t5_double_ring()
    micro_ref_attention()
    micro_kernel_interpret()
    micro_ring_step()
    micro_train_step()
    bench_train_step()
    bench_serve()
    bench_tune()


if __name__ == "__main__":
    main()
