"""Batched serving two ways:

* the continuous-batching paged engine (dense / MoE / MLA families):
  mixed-length requests share fixed decode slots, chunked prefill
  interleaves with batched decode, finished sequences retire in place;
* the fixed-batch contiguous baseline (``generate``) for families the
  engine does not page (here: a hybrid SSM model with O(1) state).

    PYTHONPATH=src python examples/serve_batched.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_reduced
from repro.core.plan import build_plan
from repro.launch.serve import generate
from repro.models.model import init_params
from repro.serve import SamplingParams, ServeEngine


def main():
    rng = np.random.default_rng(0)
    for arch in ("qwen3-1.7b", "deepseek-v2-lite-16b"):
        cfg = get_reduced(arch)
        plan = build_plan(cfg, devices=jax.devices()[:1])
        params = init_params(cfg, jax.random.PRNGKey(0))
        spec = plan.serve_spec(page_size=8, max_batch=2, max_seq_len=64,
                               prefill_chunk=16)
        with plan.mesh:
            eng = ServeEngine(plan, params, spec)
            for i in range(4):          # mixed-length request stream
                eng.submit(rng.integers(0, cfg.vocab, size=10 + 6 * i),
                           SamplingParams(temperature=0.7, top_p=0.9,
                                          seed=i),
                           max_new_tokens=4 + 2 * i)
            res = eng.run()
        print(f"{arch}: {res['generated']} tokens from 4 requests on "
              f"{spec.max_batch} slots "
              f"({res['engine_steps']} engine steps, "
              f"{eng.decode_traces} decode trace)")

    cfg = get_reduced("falcon-mamba-7b")       # no paged path: baseline
    plan = build_plan(cfg, devices=jax.devices()[:1])
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, size=(2, 24)))
    with plan.mesh:
        out = generate(params, cfg, plan.rt, tokens, gen=8)
    print(f"falcon-mamba-7b: prompt (2, 24) -> generated {out.shape}")


if __name__ == "__main__":
    main()
