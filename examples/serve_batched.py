"""Batched serving: prefill + greedy decode with context-sharded KV caches
(flash-decoding combine), incl. a hybrid SSM model with O(1) state.

    PYTHONPATH=src python examples/serve_batched.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax, jax.numpy as jnp
from repro.configs import get_reduced
from repro.core.plan import build_plan
from repro.launch.serve import generate
from repro.models.model import init_params


def main():
    for arch in ("qwen3-1.7b", "deepseek-v2-lite-16b", "falcon-mamba-7b"):
        cfg = get_reduced(arch)
        plan = build_plan(cfg, devices=jax.devices()[:1])
        params = init_params(cfg, jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                                    cfg.vocab)
        with plan.mesh:
            out = generate(params, cfg, plan.rt, tokens, gen=8)
        print(f"{arch}: prompt (2, 24) -> generated {out.shape}")


if __name__ == "__main__":
    main()
