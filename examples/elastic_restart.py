"""Fault tolerance demo: train, 'lose a node', restore the checkpoint onto
a different parallel layout (elastic resharding), keep training.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import sys, os, tempfile
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
from repro.configs import get_reduced
from repro.core.plan import build_plan
from repro.runtime.resilience import elastic_plan
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    cfg = get_reduced("qwen3-1.7b")
    with tempfile.TemporaryDirectory() as d:
        def mk(steps):
            plan = build_plan(cfg,
                              opt=OptConfig(lr=3e-3, total_steps=steps),
                              devices=jax.devices()[:1],
                              seq_len=64, global_batch=8)
            return Trainer(plan, plan.data_config(64, 8),
                           TrainerConfig(num_steps=steps, ckpt_dir=d,
                                         ckpt_every=10, log_every=10))

        t1 = mk(20)
        losses = t1.run()
        print(f"phase 1: {losses[0]:.3f} -> {losses[-1]:.3f}; "
              f"checkpointed at step 20")
        # "failure": new trainer = new process; restores & continues.
        # elastic_plan picks a layout for whatever chips survive:
        print("elastic plan for 192 healthy chips:",
              elastic_plan(192, kv_heads=8, n_heads=16))
        t2 = mk(30)
        assert t2.start_step == 20
        more = t2.run()
        print(f"phase 2 (resumed): -> {more[-1]:.3f}")
        assert more[-1] < losses[0]


if __name__ == "__main__":
    main()
