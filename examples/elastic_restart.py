"""Fault tolerance demo on the CheckpointManager: periodic async saves,
a SIGTERM "preemption" flushed at the next step boundary, then an elastic
restart that restores the sharded checkpoint onto a *different* parallel
layout (dp=2/ZeRO extent 2 -> dp=4/extent 4) and keeps training.

    PYTHONPATH=src python examples/elastic_restart.py
(uses 8 fake host devices; re-execs itself with XLA_FLAGS)
"""
import os, signal, sys, tempfile
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
from repro.configs import get_reduced
from repro.core.plan import build_plan
from repro.core.topology import ParallelConfig
from repro.runtime import checkpoint as ckpt
from repro.runtime.resilience import elastic_plan
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    cfg = get_reduced("qwen3-1.7b")
    with tempfile.TemporaryDirectory() as d:
        def mk(dp, steps, every):
            # same opt schedule across phases: the restore changes the
            # layout, never the training trajectory
            plan = build_plan(cfg, ParallelConfig(dp=dp),
                              devices=jax.devices()[:dp],
                              opt=OptConfig(lr=3e-3, total_steps=30),
                              seq_len=64, global_batch=8, zero="dp",
                              impl="ref")
            return plan, Trainer(plan, plan.data_config(64, 8),
                                 TrainerConfig(num_steps=steps, ckpt_dir=d,
                                               ckpt_every=every,
                                               log_every=5))

        # phase 1: dp=2 (ZeRO extent 2), async saves every 4 steps
        plan1, t1 = mk(2, 10, 4)
        print(plan1.describe())
        losses = t1.run()
        print(f"phase 1 (dp=2): {losses[0]:.3f} -> {losses[-1]:.3f}; "
              f"saved steps {ckpt.list_steps(d)}")

        # phase 2: a preemption notice lands mid-run — the installed
        # PreemptionGuard defers it to the next step boundary, where the
        # trainer flushes a final checkpoint and stops cleanly
        _, t2 = mk(2, 30, 100)
        assert t2.start_step == 8, t2.start_step      # resumed, no replay
        os.kill(os.getpid(), signal.SIGTERM)
        more = t2.run()
        saved = ckpt.latest_step(d)
        print(f"phase 2: SIGTERM after resume at step 8 -> ran "
              f"{len(more)} step(s), flushed step {saved}")
        assert saved == t2.start_step + len(more)

        # "failure": restart on a different layout.  elastic_plan picks a
        # grid for whatever chips survive; here we restore the extent-2
        # checkpoint straight onto dp=4 (extent 4) — a reshard at load
        # time, not a migration.
        print("elastic plan for 192 healthy chips:",
              elastic_plan(192, kv_heads=8, n_heads=16))
        plan3, t3 = mk(4, 16, 100)
        assert t3.start_step == saved
        m = t3.ckpter.manifest()
        print(f"phase 3: restored step {saved} (saved under dp="
              f"{m['plan']['dp']}, ZeRO extent {m['plan']['zero_extent']}, "
              f"{m['bytes_per_host']} bytes/host) onto dp=4, extent "
              f"{plan3.mem['zero_extent']}")
        final = t3.run()
        print(f"phase 3 (dp=4, resumed): -> {final[-1]:.3f}")
        assert final[-1] < losses[0]


if __name__ == "__main__":
    main()
