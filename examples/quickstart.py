"""Quickstart: train a tiny qwen3-family model with 2D-Attention end to end
on CPU (the same code path the production launcher uses).

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
import logging

import jax

from repro.configs import get_reduced
from repro.core.plan import build_plan
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    logging.basicConfig(level=logging.INFO)
    cfg = get_reduced("qwen3-1.7b")
    # one plan = mesh + placement + ZeRO + remat + microbatching; scale by
    # passing a bigger ParallelConfig / grad_accum
    plan = build_plan(cfg, opt=OptConfig(lr=3e-3, warmup_steps=5,
                                         total_steps=60),
                      devices=jax.devices()[:1], grad_accum=2,
                      seq_len=128, global_batch=8)
    print(plan.describe())
    trainer = Trainer(plan, plan.data_config(seq_len=128, global_batch=8),
                      TrainerConfig(num_steps=60, log_every=10))
    losses = trainer.run()
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} (should decrease)")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
