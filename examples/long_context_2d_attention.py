"""2D-Attention on a multi-device mesh: the paper's core mechanism, shown
directly against the single-device oracle — zigzag layout, head×context
grid, Double-Ring, GQA KV replication, forward AND backward.

    PYTHONPATH=src python examples/long_context_2d_attention.py
(uses 8 fake host devices; re-execs itself with XLA_FLAGS)
"""
import os, sys
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_reduced
from repro.core.plan import build_plan
from repro.core.topology import ParallelConfig
from repro.core.attention2d import attention_2d
from repro.core.zigzag import to_zigzag, from_zigzag
from repro.kernels.ref import attention_ref


def main():
    rng = np.random.default_rng(0)
    B, S, H, HKV, D = 1, 512, 8, 4, 32
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, HKV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, HKV, D)), jnp.float32)

    # hp=2 × (outer=2 × inner=2) = 8-way sequence parallelism; the plan
    # owns the mesh/placement and the Attn2DConfig
    pc = ParallelConfig(hp=2, cp_outer=2, cp_inner=2,
                        placement="context_first")
    plan = build_plan(get_reduced("qwen3-1.7b"), pc, impl="ref")
    print(plan.describe())
    mesh = plan.mesh
    cfg = plan.attn2d(causal=True, zigzag=True)

    def loss(q, k, v):
        qz, kz, vz = (to_zigzag(x, pc.cp) for x in (q, k, v))
        with mesh:
            out = attention_2d(qz, kz, vz, mesh=mesh, cfg=cfg)
        return (from_zigzag(out, pc.cp) ** 2).sum()

    with mesh:
        val, grads = jax.value_and_grad(loss, argnums=(0, 1, 2))(q, k, v)
    ref_out, _ = attention_ref(q, k, v, causal=True)
    ref_val = (ref_out ** 2).sum()
    print(f"2D-Attention loss {float(val):.4f} vs oracle "
          f"{float(ref_val):.4f} (diff {abs(float(val-ref_val)):.2e})")
    print("gradients flow through SeqAlltoAll + Double-Ring:",
          [g.shape for g in grads])
    assert abs(float(val - ref_val)) < 1e-2


if __name__ == "__main__":
    main()
