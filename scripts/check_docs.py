"""Docs drift gate: every command the docs show must actually parse.

Scans README.md, docs/*.md and benchmarks/EXPERIMENTS.md for

* fenced ``bash``/``sh``/``shell`` blocks — each command line is checked:
  referenced script/example files must exist, ``python -m repro.*``
  modules must resolve to a source file, and every ``--flag`` the docs
  pass must appear as an ``add_argument`` in that module's source (the
  static check that catches renamed/removed launcher flags);
* ``python -m repro.launch.*`` modules are additionally *run* with
  ``--help`` (unless ``--static``) — the "does it parse" proof;
* relative markdown links — the target file must exist (dead-link
  detection; http(s)/mailto/anchors are ignored).

Exit code 1 with a consolidated report when anything drifted.  Wired
into ``scripts/check.sh --fast`` and CI.

    python scripts/check_docs.py [--static] [--verbose]
"""
from __future__ import annotations

import argparse
import os
import re
import shlex
import subprocess
import sys

DOC_GLOBS = ["README.md", "docs", "benchmarks/EXPERIMENTS.md"]
SHELL_INFO = {"bash", "sh", "shell", "console", ""}
FENCE_RE = re.compile(r"^```(\w*)\s*$")
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
ARG_RE = re.compile(r"""add_argument\(\s*['"](--[A-Za-z0-9-]+)['"]""")
#: launchers share flag builders in repro/launch/args.py — a module
#: importing it accepts those flags too (the static check follows the
#: import; the live --help run proves it for real)
SHARED_ARGS_RE = re.compile(r"repro\.launch(?:\.args|\s+import\s+args)")


def doc_files(root: str) -> list[str]:
    out = []
    for entry in DOC_GLOBS:
        path = os.path.join(root, entry)
        if os.path.isdir(path):
            out += sorted(os.path.join(path, f) for f in os.listdir(path)
                          if f.endswith(".md"))
        elif os.path.exists(path):
            out.append(path)
    return out


def shell_commands(text: str):
    """Yield (lineno, command) from fenced shell blocks, with ``\\``
    continuations joined and comments stripped."""
    in_block, shell = False, False
    pending, pending_ln = "", 0
    for ln, line in enumerate(text.splitlines(), 1):
        m = FENCE_RE.match(line.strip())
        if m:
            if in_block:
                in_block = False
            else:
                in_block, shell = True, m.group(1).lower() in SHELL_INFO
            continue
        if not (in_block and shell):
            continue
        line = line.split("#", 1)[0].rstrip()
        if not line.strip():
            continue
        if pending:
            line = pending + " " + line.strip()
        if line.rstrip().endswith("\\"):
            pending, pending_ln = line.rstrip()[:-1], pending_ln or ln
            continue
        yield (pending_ln or ln), line.strip()
        pending, pending_ln = "", 0


def strip_env(tokens: list[str]) -> list[str]:
    while tokens and re.match(r"^[A-Za-z_][A-Za-z0-9_]*=", tokens[0]):
        tokens = tokens[1:]
    return tokens


def module_source(root: str, module: str) -> str | None:
    path = os.path.join(root, "src", *module.split(".")) + ".py"
    return path if os.path.exists(path) else None


def module_flags(path: str) -> set[str]:
    """Flags a module accepts: its own ``add_argument`` calls, plus the
    shared builders' when it imports ``repro.launch.args``."""
    with open(path) as f:
        src = f.read()
    flags = set(ARG_RE.findall(src))
    if SHARED_ARGS_RE.search(src):
        shared = os.path.join(os.path.dirname(path), "args.py")
        if os.path.exists(shared):
            with open(shared) as f:
                flags |= set(ARG_RE.findall(f.read()))
    return flags


def check_command(root: str, doc: str, ln: int, cmd: str, errors: list,
                  modules_used: set):
    try:
        tokens = strip_env(shlex.split(cmd))
    except ValueError:
        errors.append(f"{doc}:{ln}: unparseable shell line: {cmd!r}")
        return
    if not tokens:
        return
    exe = tokens[0]
    if exe in ("bash", "sh") and len(tokens) > 1:
        target = tokens[1]
        if not os.path.exists(os.path.join(root, target)):
            errors.append(f"{doc}:{ln}: missing script {target!r}")
        return
    if exe.endswith(".sh") or exe.startswith("scripts/"):
        if not os.path.exists(os.path.join(root, exe)):
            errors.append(f"{doc}:{ln}: missing script {exe!r}")
        return
    if exe not in ("python", "python3"):
        return                                   # pip, git, … — not ours
    rest = tokens[1:]
    if rest[:1] == ["-m"]:
        if len(rest) < 2:
            return
        module, args = rest[1], rest[2:]
        if not module.startswith("repro."):
            return                               # pytest etc.
        src = module_source(root, module)
        if src is None:
            errors.append(f"{doc}:{ln}: module {module!r} does not exist")
            return
        modules_used.add(module)
        known = module_flags(src)
        for flag in (t.split("=", 1)[0] for t in args
                     if t.startswith("--")):
            if flag not in known:
                errors.append(f"{doc}:{ln}: {module} has no {flag!r} "
                              f"(doc drift — known: {sorted(known)})")
    elif rest and rest[0].endswith(".py"):
        script = rest[0]
        if not os.path.exists(os.path.join(root, script)):
            errors.append(f"{doc}:{ln}: missing file {script!r}")
        elif script == "benchmarks/run.py" and len(rest) > 1 \
                and not rest[1].startswith("-"):
            with open(os.path.join(root, script)) as f:
                if f'"{rest[1]}"' not in f.read():
                    errors.append(f"{doc}:{ln}: benchmarks/run.py has no "
                                  f"section {rest[1]!r}")


def check_links(root: str, doc: str, text: str, errors: list):
    for ln, line in enumerate(text.splitlines(), 1):
        for target in LINK_RE.findall(line):
            if re.match(r"^[a-z]+:", target) or target.startswith("#"):
                continue                         # absolute URL / anchor
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            base = root if rel.startswith("/") else os.path.dirname(doc)
            if not os.path.exists(os.path.join(base, rel.lstrip("/"))):
                errors.append(f"{doc}:{ln}: dead link {target!r}")


def run_help(root: str, module: str, errors: list, verbose: bool):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    try:
        res = subprocess.run([sys.executable, "-m", module, "--help"],
                             capture_output=True, text=True, timeout=180,
                             cwd=root, env=env)
    except subprocess.TimeoutExpired:
        errors.append(f"{module}: --help timed out")
        return
    if res.returncode != 0:
        errors.append(f"{module}: --help exited {res.returncode}:\n"
                      f"{res.stderr.strip()[-500:]}")
    elif verbose:
        print(f"[check-docs] {module} --help ok")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--static", action="store_true",
                    help="skip the live `-m <module> --help` runs")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

    errors: list[str] = []
    modules_used: set[str] = set()
    n_cmds = 0
    docs = doc_files(root)
    for doc in docs:
        with open(doc) as f:
            text = f.read()
        rel = os.path.relpath(doc, root)
        for ln, cmd in shell_commands(text):
            n_cmds += 1
            check_command(root, rel, ln, cmd, errors, modules_used)
        check_links(root, doc, text, errors)
    if not args.static:
        for module in sorted(m for m in modules_used
                             if m.startswith("repro.launch.")):
            run_help(root, module, errors, args.verbose)
    if errors:
        print(f"[check-docs] FAILED ({len(errors)} problem(s) across "
              f"{len(docs)} docs):")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"[check-docs] ok: {n_cmds} commands, {len(docs)} docs, "
          f"{len(modules_used)} modules"
          + ("" if args.static else
         f" ({len([m for m in modules_used if m.startswith('repro.launch.')])}"
             " --help runs)"))
    return 0


if __name__ == "__main__":
    sys.exit(main())
