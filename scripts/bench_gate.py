"""Bench-regression gate: diff freshly produced BENCH_*.json against the
baselines committed at HEAD and fail on step-time regressions.

    python scripts/bench_gate.py [--tol 0.25] [--base-ref HEAD]
    python scripts/bench_gate.py --update-baselines

For every metric the gate knows about it compares the working-tree value
(the one the benches just rewrote) against ``git show HEAD:<file>`` and
fails when the *regression direction* exceeds ``tol × noise_factor``:
lower-is-better metrics (µs, latency ms) may grow, higher-is-better
(steps/s, tokens/s) may shrink.  Interpret-mode kernels and wall-clock
serving/training numbers get a 3× noise factor — interpreter overhead and
host load are not the tracked signal; the trend of each impl against
itself is.  Missing baselines (a bench introduced by the current change)
are reported and skipped, so adding a bench never blocks its own PR.
Env override: ``BENCH_GATE_TOL``.

``--update-baselines`` reruns every bench the gate tracks and rewrites
the BENCH_*.json files for you to commit.  Do this **on a quiet
machine**: the committed numbers are the baselines every later run is
diffed against, and wall-clock benches recorded under container/CI
throttling make the gate trip on healthy code (see
benchmarks/EXPERIMENTS.md §Bench gate).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

#: (file, case-key fn, [(metric, direction, noise_factor)])
LOWER, HIGHER = "lower", "higher"


def _ring_specs(case):
    noise = 3.0 if "interpret" in case["impl"] else 1.5
    return case["impl"], [("fwd_us", LOWER, noise), ("bwd_us", LOWER, noise)]


def _train_specs(case):
    return f"accum{case['grad_accum']}", [
        ("steps_per_s_sync", HIGHER, 3.0),
        ("steps_per_s_async", HIGHER, 3.0)]


def _serve_specs(case):
    return case["name"], [("tokens_per_s", HIGHER, 3.0),
                          ("p50_ms", LOWER, 3.0), ("p99_ms", LOWER, 3.0)]


def _tune_specs(case):
    # measured wall-clock of the tuner's picks (3× noise: host load);
    # predicted_ms is deliberately ungated — it moves when the cost
    # model/calibration is *intentionally* changed, not when code slows.
    return case["tag"], [("measured_ms", LOWER, 3.0)]


def _packed_specs(case):
    # gate on process-CPU time (host-load-immune on a throttled box) and
    # on the deterministic executed-block fraction; wall time is recorded
    # but ungated — interpret-mode wall on a loaded host swings >2×.
    return case["mode"], [("fwd_cpu_us", LOWER, 3.0),
                          ("bwd_cpu_us", LOWER, 3.0),
                          ("blocks_frac", LOWER, 1.0)]


def _ckpt_specs(case):
    # stall/resume are host wall-clock (3× noise: filesystem + load);
    # bytes_per_host is deterministic layout — any growth is a real
    # sharding regression, so it gets no noise allowance.
    return case["tag"], [("stall_ms", LOWER, 3.0),
                         ("resume_ms", LOWER, 3.0),
                         ("bytes_per_host", LOWER, 1.0)]


def _offload_specs(case):
    # max-seq rows come straight from the deterministic plan memory model
    # — no noise allowance: the seq ratio vs the resident baseline (≥ 4×
    # at depth 8) is the headline claim.  Step rows are host wall-clock
    # of the ref-impl pipeline (3× noise); the chunked row's stall count
    # is deterministic pipeline correctness (prefetch must stay ahead).
    if case["kind"] == "max_seq":
        return case["tag"], [("max_seq_at_budget", HIGHER, 1.0),
                             ("seq_ratio", HIGHER, 1.0)]
    specs = [("wall_us", LOWER, 3.0), ("cpu_us", LOWER, 3.0)]
    if case["mode"] == "chunked":
        specs += [("overhead", LOWER, 3.0), ("stalls", LOWER, 1.0)]
    return case["tag"], specs


#: bench file -> case-spec fn (see the (file, key, metrics) contract above)
FILES = {
    "BENCH_ring.json": _ring_specs,
    "BENCH_train_step.json": _train_specs,
    "BENCH_serve.json": _serve_specs,
    "BENCH_tune.json": _tune_specs,
    "BENCH_packed.json": _packed_specs,
    "BENCH_ckpt.json": _ckpt_specs,
    "BENCH_offload.json": _offload_specs,
}

BENCH_CMDS = {
    "BENCH_ring.json": "ring",
    "BENCH_train_step.json": "train",
    "BENCH_serve.json": "serve",
    "BENCH_tune.json": "tune",
    "BENCH_packed.json": "packed",
    "BENCH_ckpt.json": "ckpt",
    "BENCH_offload.json": "offload",
}


def update_baselines() -> int:
    """Rerun every tracked bench, rewriting the BENCH_*.json baselines."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        ["src", "."] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH")
                        else []))
    for path, sub in BENCH_CMDS.items():
        print(f"[bench-gate] regenerating {path} "
              f"(benchmarks/run.py {sub}) ...")
        subprocess.run([sys.executable, "benchmarks/run.py", sub],
                       check=True, env=env)
    print("[bench-gate] baselines rewritten: "
          + ", ".join(BENCH_CMDS)
          + "\n[bench-gate] review + commit them — and only from a quiet "
            "machine (throttled/loaded hosts bake noise into the gate; "
            "see benchmarks/EXPERIMENTS.md)")
    return 0


def load_baseline(path: str, ref: str):
    try:
        out = subprocess.run(["git", "show", f"{ref}:{path}"],
                             capture_output=True, text=True, check=True)
        return json.loads(out.stdout)
    except (subprocess.CalledProcessError, json.JSONDecodeError):
        return None


def compare(fresh: dict, base: dict, spec_fn, tol: float):
    """Yields (case.metric, base, fresh, limit, regressed)."""
    base_by_key = {}
    for case in base.get("cases", []):
        key, _ = spec_fn(case)
        base_by_key[key] = case
    for case in fresh.get("cases", []):
        key, metrics = spec_fn(case)
        ref = base_by_key.get(key)
        if ref is None:
            continue
        for metric, direction, noise in metrics:
            if metric not in case or metric not in ref:
                continue
            b, f = float(ref[metric]), float(case[metric])
            limit = tol * noise
            if b <= 0:
                continue
            delta = (f - b) / b if direction == LOWER else (b - f) / b
            yield f"{key}.{metric}", b, f, limit, delta > limit


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tol", type=float,
                    default=float(os.environ.get("BENCH_GATE_TOL", 0.25)))
    ap.add_argument("--base-ref", default="HEAD")
    ap.add_argument("--update-baselines", action="store_true",
                    help="rerun every tracked bench and rewrite the "
                         "BENCH_*.json baselines (run on a quiet machine, "
                         "then commit)")
    args = ap.parse_args()

    os.chdir(os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))
    if args.update_baselines:
        return update_baselines()
    failures, checked = [], 0
    for path, spec_fn in FILES.items():
        if not os.path.exists(path):
            print(f"[bench-gate] {path}: no fresh file, skipped")
            continue
        with open(path) as f:
            fresh = json.load(f)
        base = load_baseline(path, args.base_ref)
        if base is None:
            print(f"[bench-gate] {path}: no committed baseline at "
                  f"{args.base_ref}, skipped (new bench)")
            continue
        for name, b, f_, limit, bad in compare(fresh, base, spec_fn,
                                               args.tol):
            checked += 1
            tag = "REGRESSION" if bad else "ok"
            print(f"[bench-gate] {path}:{name} base={b:.2f} "
                  f"fresh={f_:.2f} limit=+{limit:.0%} {tag}")
            if bad:
                failures.append(f"{path}:{name}")
    if failures:
        print(f"[bench-gate] FAILED: {len(failures)} regression(s): "
              f"{', '.join(failures)}")
        return 1
    print(f"[bench-gate] passed ({checked} metrics within tolerance)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
