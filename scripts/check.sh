#!/usr/bin/env bash
# Tiered gate.  Run from anywhere:
#     scripts/check.sh --fast    # tier-1 pytest (single-device tests;
#                                # dist/slow deselected) + docs check +
#                                # PlanTuner enumerate+score smoke
#     scripts/check.sh           # full: all tests + benches + bench gate +
#                                # plan/tune smoke + serve smoke + packed
#                                # train smoke + elastic-restart smoke
# The full tier rewrites BENCH_ring.json / BENCH_train_step.json /
# BENCH_serve.json / BENCH_tune.json / BENCH_packed.json /
# BENCH_ckpt.json / BENCH_offload.json and diffs them against the committed
# baselines (scripts/bench_gate.py) so perf regressions on the ring hot
# path, the (accumulated) train step, the serving engine, and the tuner's
# picks show up immediately; the dryrun --plan [--tune] invocations fail
# fast on ExecutionPlan/PlanTuner regressions for production cells of one
# arch without compiling anything.  Baselines are refreshed with
# `python scripts/bench_gate.py --update-baselines` on a quiet machine.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--fast" ]]; then
    python -m pytest -q -m "not dist and not slow"
    python scripts/check_docs.py
    python -m repro.launch.tune --arch qwen3-1.7b --smoke \
        --out /tmp/check_tuned_plan.json
    exit 0
fi

python -m pytest -x -q
python scripts/check_docs.py
python benchmarks/run.py ring
python benchmarks/run.py train
python benchmarks/run.py serve
python benchmarks/run.py tune
python benchmarks/run.py packed
python benchmarks/run.py ckpt
python benchmarks/run.py offload
python scripts/bench_gate.py
python examples/elastic_restart.py
python -m repro.launch.dryrun --plan --arch qwen3-1.7b --shape all
python -m repro.launch.dryrun --plan --tune --arch qwen3-1.7b \
    --shape train_4k
python -m repro.launch.serve --arch qwen3-1.7b --smoke \
    --prompt-len 24 --gen 8 --batch 2 --requests 4
python -m repro.launch.train --arch qwen3-1.7b --smoke --pack --steps 2
