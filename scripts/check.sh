#!/usr/bin/env bash
# Tiered gate.  Run from anywhere:
#     scripts/check.sh --fast    # tier-1 pytest only (single-device tests;
#                                # dist/slow suites deselected by marker)
#     scripts/check.sh           # full: all tests + benches + bench gate +
#                                # plan smoke + serve smoke
# The full tier rewrites BENCH_ring.json / BENCH_train_step.json /
# BENCH_serve.json and diffs them against the committed baselines
# (scripts/bench_gate.py) so perf regressions on the ring hot path, the
# (accumulated) train step, and the serving engine show up immediately;
# the dryrun --plan invocation fails fast on ExecutionPlan regressions
# for every production cell of one arch without compiling anything.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--fast" ]]; then
    python -m pytest -q -m "not dist and not slow"
    exit 0
fi

python -m pytest -x -q
python benchmarks/run.py ring
python benchmarks/run.py train
python benchmarks/run.py serve
python scripts/bench_gate.py
python -m repro.launch.dryrun --plan --arch qwen3-1.7b --shape all
python -m repro.launch.serve --arch qwen3-1.7b --smoke \
    --prompt-len 24 --gen 8 --batch 2 --requests 4
