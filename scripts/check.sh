#!/usr/bin/env bash
# Tier-1 gate + benches + plan smoke.  Run from anywhere:
#     scripts/check.sh
# Tests must pass; the benches rewrite BENCH_ring.json /
# BENCH_train_step.json so perf regressions on the ring hot path and the
# (accumulated) train step show up in the BENCH_* trajectory; the dryrun
# --plan invocation fails fast on ExecutionPlan regressions for every
# production cell of one arch without compiling anything.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q
python benchmarks/run.py ring
python benchmarks/run.py train
python -m repro.launch.dryrun --plan --arch qwen3-1.7b --shape all
