#!/usr/bin/env bash
# Tier-1 gate + ring micro-benchmark.  Run from anywhere:
#     scripts/check.sh
# Tests must pass; the bench rewrites BENCH_ring.json so perf regressions
# on the ring hot path show up in the BENCH_* trajectory.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q
python benchmarks/run.py ring
