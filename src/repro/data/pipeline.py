"""Deterministic synthetic data pipelines with zigzag context reordering.

Two sources share one batch contract:

* ``SyntheticLM`` — one document per sequence (the original stream);
* ``PackedLM`` — variable-length documents bin-packed into the sequence
  window, emitting per-token ``doc_start`` boundary tables (block-causal
  masking through the 2D-Attention stack) plus host-side
  ``boundaries()``/``segments()``/``documents()`` views.

The paper's context-first placement requires "a post-processing function
within the data loader to adjust input sequence placement at the start of
each batch" (§4.4) — that function is ``_apply_layout``: every per-token
array (tokens/labels/positions, and ``doc_start`` for packed batches) is
permuted into the zigzag physical layout once per batch, on the host, so
no on-the-fly device data movement is needed.

Determinism: batch ``i`` depends only on (seed, i) — restart-after-failure
resumes mid-epoch by step index alone (runtime/checkpoint.py stores the
step); packed document content additionally keys on the document id, so
packing placement never changes a document's bytes.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.zigzag import zigzag_indices
from repro.models.model import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int          # in sequences, across all microbatches
    cp: int = 1                # context size for zigzag layout
    zigzag: bool = True
    grad_accum: int = 1        # microbatches per step; batches come out
                               # shaped (accum, global_batch//accum, ...)
    seed: int = 0
    pad_frac: float = 0.0      # fraction of tail tokens padded (-1 labels)
    #: PackedLM: (min, max) document length, inclusive; None defaults to
    #: (max(8, seq_len // 8), seq_len) — a mixed-length stream
    doc_len_range: tuple | None = None


def _apply_layout(arr, perm, accum: int):
    """Zigzag data-loader permutation (seq axis), then the microbatch
    split: (B, S, ...) -> (accum, B // accum, S, ...)."""
    arr = arr[:, perm]
    if accum > 1:
        arr = arr.reshape((accum, arr.shape[0] // accum) + arr.shape[1:])
    return arr


class _StepIndexed:
    """Step-indexed resume contract shared by both sources.

    ``batch(step)`` depends only on ``(cfg.seed, step)``, so resuming
    from a checkpoint is a *skip*, not a stream replay:
    ``iter_batches(start_step)`` indexes straight to the step after the
    restore point and the resumed run sees exactly the batches the
    uninterrupted run would have (the kill-and-resume loss-parity check
    in tests/_dist_checks.py pins this).
    """

    def iter_batches(self, start_step: int = 0,
                     num_steps: int | None = None):
        """Yield ``(step, batch)`` from ``start_step``, for ``num_steps``
        steps (unbounded when None)."""
        step = start_step
        while num_steps is None or step < start_step + num_steps:
            yield step, self.batch(step)
            step += 1


class SyntheticLM(_StepIndexed):
    """Synthetic next-token corpus: a fixed random Markov-ish stream.

    With ``grad_accum > 1`` every batch leaf carries a leading
    accumulation axis — ``(accum, microbatch, ...)`` — matching the
    ``lax.scan`` microbatch loop in ``train/train_step.py``.
    """

    def __init__(self, cfg: DataConfig, model_cfg: ModelConfig | None = None):
        assert cfg.global_batch % cfg.grad_accum == 0, \
            (cfg.global_batch, cfg.grad_accum)
        self.cfg = cfg
        self.model_cfg = model_cfg
        s, cp = cfg.seq_len, cfg.cp
        if cfg.zigzag and cp > 1:
            self._perm = zigzag_indices(s, cp)
        else:
            self._perm = np.arange(s)

    def _layout(self, arr):
        return _apply_layout(arr, self._perm, self.cfg.grad_accum)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, s = cfg.global_batch, cfg.seq_len
        # Learnable stream: a fixed affine map with 10% uniform noise, so a
        # model can reduce loss toward the noise floor (smoke tests assert
        # loss decrease; uniform-random tokens would be irreducible).
        stream = np.empty((b, s + 1), dtype=np.int64)
        stream[:, 0] = rng.integers(1, cfg.vocab, size=b)
        noise = rng.random((b, s)) < 0.1
        noise_tok = rng.integers(1, cfg.vocab, size=(b, s))
        for t in range(s):
            nxt = (stream[:, t] * 31 + 7) % (cfg.vocab - 1) + 1
            stream[:, t + 1] = np.where(noise[:, t], noise_tok[:, t], nxt)
        stream = stream.astype(np.int32)
        tokens = stream[:, :-1]
        labels = stream[:, 1:].copy()
        if cfg.pad_frac > 0:
            n_pad = int(s * cfg.pad_frac)
            if n_pad:
                labels[:, -n_pad:] = -1
        positions = np.broadcast_to(np.arange(s, dtype=np.int32)[None],
                                    (b, s)).copy()
        out = {"tokens": self._layout(tokens),
               "labels": self._layout(labels),
               "positions": self._layout(positions)}
        if self.model_cfg is not None and self.model_cfg.family == "encdec":
            frames = rng.standard_normal(
                (b, self.model_cfg.enc_frames, self.model_cfg.d_model)
            ).astype(np.float32)
            a = cfg.grad_accum
            if a > 1:     # microbatch split only; no seq perm on frames
                frames = frames.reshape((a, b // a) + frames.shape[1:])
            out["frames"] = frames
        return out


def _doc_stream(vocab: int, length: int, rng) -> np.ndarray:
    """One document: the same learnable affine-map-with-noise stream as
    SyntheticLM, restarted per document (so any cross-document attention
    leak shows up as a loss/grad mismatch, not a wash)."""
    stream = np.empty(length, dtype=np.int64)
    stream[0] = rng.integers(1, vocab)
    noise = rng.random(length) < 0.1
    noise_tok = rng.integers(1, vocab, size=length)
    for t in range(length - 1):
        nxt = (stream[t] * 31 + 7) % (vocab - 1) + 1
        stream[t + 1] = noise_tok[t] if noise[t] else nxt
    return stream.astype(np.int32)


class PackedLM(_StepIndexed):
    """Packed-document corpus: variable-length synthetic documents
    bin-packed into fixed ``(accum, microbatch, seq)`` batches.

    Every batch leaf gets the same zigzag layout + microbatch split as
    ``SyntheticLM``; in addition each batch carries ``doc_start`` — the
    per-token table of logical document start positions that drives
    block-causal (per-document) masking through the 2D-Attention stack
    (see ``kernels/ref.py::BandMask`` and ``core/attention2d.py``).

    Packing is deterministic per ``(seed, step)``: document lengths are
    drawn from ``cfg.doc_len_range``, then first-fit-decreasing packed
    into ``global_batch`` bins of ``seq_len`` tokens; bins' tail gaps are
    padded (label ``-1``, doc_start = the gap's own start, so pad tokens
    attend only one another and train nothing).  Per-document content is
    seeded by ``(seed, step, doc_id)`` so a document's tokens do not
    depend on where packing placed it.

    Labels are next-token *within* each document — the last token of a
    document never predicts the next document's first token.  Positions
    restart at 0 per document (rotary phases match an unpacked run).
    """

    def __init__(self, cfg: DataConfig, model_cfg: ModelConfig | None = None):
        assert cfg.global_batch % cfg.grad_accum == 0, \
            (cfg.global_batch, cfg.grad_accum)
        assert model_cfg is None or model_cfg.family != "encdec", \
            "packing is a decoder-LM feature"
        self.cfg = cfg
        self.model_cfg = model_cfg
        s, cp = cfg.seq_len, cfg.cp
        if cfg.zigzag and cp > 1:
            self._perm = zigzag_indices(s, cp)
        else:
            self._perm = np.arange(s)
        lo, hi = cfg.doc_len_range or (max(8, s // 8), s)
        assert 2 <= lo <= hi <= s, (lo, hi, s)
        self._range = (int(lo), int(hi))
        # one-entry caches: batch()/boundaries()/segments() are different
        # views of the same step's document set — the O(B·S) host-side
        # generation runs once per step, not once per view
        self._docs_cache: tuple[int, list] | None = None
        self._asm_cache: tuple[int, tuple] | None = None

    def documents(self, step: int) -> list[list[dict]]:
        """The step's bin-packed document set, in logical order: one list
        per sequence of ``{"start", "tokens", "labels", "positions"}``
        (the per-sequence document-boundary table, with content)."""
        if self._docs_cache is not None and self._docs_cache[0] == step:
            return self._docs_cache[1]
        cfg = self.cfg
        b, s = cfg.global_batch, cfg.seq_len
        lo, hi = self._range
        rng = np.random.default_rng((cfg.seed, step, 91))
        lens = []
        while sum(lens) < b * s:               # over-draw the pool
            lens.append(int(rng.integers(lo, hi + 1)))
        order = sorted(range(len(lens)), key=lambda i: -lens[i])
        bins: list[list[int]] = [[] for _ in range(b)]
        space = [s] * b
        for idx in order:                      # first-fit-decreasing
            for bi in range(b):
                if space[bi] >= lens[idx]:
                    bins[bi].append(idx)
                    space[bi] -= lens[idx]
                    break
        out = []
        for bi in range(b):
            docs, start = [], 0
            for idx in bins[bi]:
                l = lens[idx]
                crng = np.random.default_rng((cfg.seed, step, 7, idx))
                tokens = _doc_stream(cfg.vocab, l, crng)
                labels = np.concatenate(
                    [tokens[1:], np.full(1, -1, np.int32)])
                docs.append({"start": start, "tokens": tokens,
                             "labels": labels,
                             "positions": np.arange(l, dtype=np.int32)})
                start += l
            out.append(docs)
        self._docs_cache = (step, out)
        return out

    def boundaries(self, step: int) -> list[list[tuple[int, int]]]:
        """Per-sequence ``(start, length)`` document-boundary table."""
        return [[(d["start"], len(d["tokens"])) for d in docs]
                for docs in self.documents(step)]

    def _assemble(self, step: int):
        """Logical-order (B, S) arrays before layout."""
        if self._asm_cache is not None and self._asm_cache[0] == step:
            return self._asm_cache[1]
        cfg = self.cfg
        b, s = cfg.global_batch, cfg.seq_len
        tokens = np.zeros((b, s), np.int32)
        labels = np.full((b, s), -1, np.int32)
        positions = np.zeros((b, s), np.int32)
        doc_start = np.zeros((b, s), np.int32)
        segments = np.full((b, s), -1, np.int32)
        for bi, docs in enumerate(self.documents(step)):
            end = 0
            for di, d in enumerate(docs):
                s0, l = d["start"], len(d["tokens"])
                tokens[bi, s0:s0 + l] = d["tokens"]
                labels[bi, s0:s0 + l] = d["labels"]
                positions[bi, s0:s0 + l] = d["positions"]
                doc_start[bi, s0:s0 + l] = s0
                segments[bi, s0:s0 + l] = di
                end = s0 + l
            doc_start[bi, end:] = end          # tail pad: its own document
        out = (tokens, labels, positions, doc_start, segments)
        self._asm_cache = (step, out)
        return out

    def segments(self, step: int) -> np.ndarray:
        """(B, S) int32 per-token segment (document) ids in logical
        order; ``-1`` marks pad slots."""
        return self._assemble(step)[4]

    def batch(self, step: int) -> dict:
        tokens, labels, positions, doc_start, _ = self._assemble(step)
        a = self.cfg.grad_accum
        return {"tokens": _apply_layout(tokens, self._perm, a),
                "labels": _apply_layout(labels, self._perm, a),
                "positions": _apply_layout(positions, self._perm, a),
                "doc_start": _apply_layout(doc_start, self._perm, a)}
