"""Deterministic synthetic data pipeline with zigzag context reordering.

The paper's context-first placement requires "a post-processing function
within the data loader to adjust input sequence placement at the start of
each batch" (§4.4) — that function is ``_layout``: the token/label/position
arrays are permuted into the zigzag physical layout once per batch, on the
host, so no on-the-fly device data movement is needed.

Determinism: batch ``i`` depends only on (seed, i) — restart-after-failure
resumes mid-epoch by step index alone (runtime/checkpoint.py stores the
step).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.zigzag import zigzag_indices
from repro.models.model import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int          # in sequences, across all microbatches
    cp: int = 1                # context size for zigzag layout
    zigzag: bool = True
    grad_accum: int = 1        # microbatches per step; batches come out
                               # shaped (accum, global_batch//accum, ...)
    seed: int = 0
    pad_frac: float = 0.0      # fraction of tail tokens padded (-1 labels)


class SyntheticLM:
    """Synthetic next-token corpus: a fixed random Markov-ish stream.

    With ``grad_accum > 1`` every batch leaf carries a leading
    accumulation axis — ``(accum, microbatch, ...)`` — matching the
    ``lax.scan`` microbatch loop in ``train/train_step.py``.
    """

    def __init__(self, cfg: DataConfig, model_cfg: ModelConfig | None = None):
        assert cfg.global_batch % cfg.grad_accum == 0, \
            (cfg.global_batch, cfg.grad_accum)
        self.cfg = cfg
        self.model_cfg = model_cfg
        s, cp = cfg.seq_len, cfg.cp
        if cfg.zigzag and cp > 1:
            self._perm = zigzag_indices(s, cp)
        else:
            self._perm = np.arange(s)

    def _layout(self, arr):
        """Zigzag data-loader permutation (seq axis), then the microbatch
        split: (B, S, ...) -> (accum, B // accum, S, ...)."""
        arr = arr[:, self._perm]
        a = self.cfg.grad_accum
        if a > 1:
            arr = arr.reshape((a, arr.shape[0] // a) + arr.shape[1:])
        return arr

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, s = cfg.global_batch, cfg.seq_len
        # Learnable stream: a fixed affine map with 10% uniform noise, so a
        # model can reduce loss toward the noise floor (smoke tests assert
        # loss decrease; uniform-random tokens would be irreducible).
        stream = np.empty((b, s + 1), dtype=np.int64)
        stream[:, 0] = rng.integers(1, cfg.vocab, size=b)
        noise = rng.random((b, s)) < 0.1
        noise_tok = rng.integers(1, cfg.vocab, size=(b, s))
        for t in range(s):
            nxt = (stream[:, t] * 31 + 7) % (cfg.vocab - 1) + 1
            stream[:, t + 1] = np.where(noise[:, t], noise_tok[:, t], nxt)
        stream = stream.astype(np.int32)
        tokens = stream[:, :-1]
        labels = stream[:, 1:].copy()
        if cfg.pad_frac > 0:
            n_pad = int(s * cfg.pad_frac)
            if n_pad:
                labels[:, -n_pad:] = -1
        positions = np.broadcast_to(np.arange(s, dtype=np.int32)[None],
                                    (b, s)).copy()
        out = {"tokens": self._layout(tokens),
               "labels": self._layout(labels),
               "positions": self._layout(positions)}
        if self.model_cfg is not None and self.model_cfg.family == "encdec":
            frames = rng.standard_normal(
                (b, self.model_cfg.enc_frames, self.model_cfg.d_model)
            ).astype(np.float32)
            a = cfg.grad_accum
            if a > 1:     # microbatch split only; no seq perm on frames
                frames = frames.reshape((a, b // a) + frames.shape[1:])
            out["frames"] = frames
        return out
