"""Pallas TPU flash-attention kernel (forward + backward).

TPU-native adaptation of FlashAttention-2 for the LoongTrain reproduction:

* ``pl.pallas_call`` with explicit ``BlockSpec`` VMEM tiling; MXU-aligned
  (multiples-of-128) Q/K blocks; fp32 accumulators in VMEM scratch.
* Bottom-right-aligned causal masking (what ring attention's diagonal step
  needs), sliding-window (local) masking, Gemma-style logit softcap, GQA via
  index-map head folding.
* Fully-masked K blocks are *skipped* via ``pl.when`` on the grid ids, so the
  compiled FLOPs of a causal call are ~half of the dense product — mirroring
  the paper's halved-FLOPs MFU accounting.
* The backward pass is two Pallas kernels (dq; dk/dv) following the
  FlashAttention-2 recomputation scheme; GQA gradients are computed per
  Q-head and group-summed in the wrapper.

Validated on CPU with ``interpret=True`` against ``ref.py`` (see
``tests/test_kernels.py``).  On real TPUs set ``interpret=False``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


class FlashParams(NamedTuple):
    """Static kernel configuration (hashable => usable as nondiff arg)."""
    causal: bool
    window: int | None
    softcap: float
    scale: float
    lq_valid: int          # number of real (unpadded) queries
    lk_valid: int          # number of real (unpadded) keys
    block_q: int
    block_k: int
    interpret: bool


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref,
                *, p: FlashParams, nk: int, delta: int):
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * p.block_q
    k_start = jk * p.block_k
    run = k_start < p.lk_valid
    if p.causal:
        # Last visible key for the last query row of this block.
        run = jnp.logical_and(
            run, k_start <= q_start + (p.block_q - 1) + delta)
    if p.window is not None:
        # First visible key for the first query row of this block.
        run = jnp.logical_and(
            run, k_start + p.block_k - 1 >= q_start + delta - (p.window - 1))

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * p.scale
        if p.softcap:
            s = p.softcap * jnp.tanh(s / p.softcap)

        qi = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (p.block_q, p.block_k), 0)
        kj = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (p.block_q, p.block_k), 1)
        mask = kj < p.lk_valid
        if p.causal:
            mask &= kj <= qi + delta
        if p.window is not None:
            mask &= kj >= qi + delta - (p.window - 1)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        # fully-masked-so-far rows: keep shift at 0 to avoid exp(inf) traps
        shift = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        pmat = jnp.exp(s - shift[:, None])
        pmat = jnp.where(mask, pmat, 0.0)
        alpha = jnp.exp(jnp.where(m_prev <= NEG_INF / 2, NEG_INF,
                                  m_prev - shift))
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, alpha)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(pmat, axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            pmat, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(jk == nk - 1)
    def _finalize():
        l = l_ref[...]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)
        m = m_ref[...]
        shift = jnp.where(m <= NEG_INF / 2, 0.0, m)
        lse_ref[0] = jnp.where(l == 0.0, NEG_INF, shift + jnp.log(l_safe))


def _fwd(q, k, v, p: FlashParams):
    """q: (B*Hq, Lq, D); k/v: (B*Hkv, Lk, D), heads folded major-to-minor.

    GQA is handled in the K/V index maps (kv row = q row // group), so the
    replicated KV is never materialized.  Returns out (BH, Lq, D),
    lse (BH, Lq) fp32.
    """
    bh, lq, d = q.shape
    bhkv, lk, _ = k.shape
    assert bh % bhkv == 0, (bh, bhkv)
    group = bh // bhkv
    nq = lq // p.block_q
    nk = lk // p.block_k
    delta = p.lk_valid - p.lq_valid

    kernel = functools.partial(_fwd_kernel, p=p, nk=nk, delta=delta)
    out, lse = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, p.block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, p.block_k, d),
                         lambda b, i, j: (b // group, j, 0)),
            pl.BlockSpec((1, p.block_k, d),
                         lambda b, i, j: (b // group, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, p.block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, p.block_q), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, lq, d), q.dtype),
            jax.ShapeDtypeStruct((bh, lq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((p.block_q, d), jnp.float32),
            pltpu.VMEM((p.block_q,), jnp.float32),
            pltpu.VMEM((p.block_q,), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=p.interpret,
    )(q, k, v)
    return out, lse


# ---------------------------------------------------------------------------
# Backward
# ---------------------------------------------------------------------------

def _recompute_p(q, k, q_start, k_start, p: FlashParams, delta):
    """Recompute softcapped+masked scores; returns (s_capped, mask, s_raw)."""
    s_raw = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * p.scale
    s = p.softcap * jnp.tanh(s_raw / p.softcap) if p.softcap else s_raw
    bq, bk = s.shape
    qi = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kj = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kj < p.lk_valid
    if p.causal:
        mask &= kj <= qi + delta
    if p.window is not None:
        mask &= kj >= qi + delta - (p.window - 1)
    return s, mask, s_raw


def _ds_from_dp(dp, pmat, s_capped, s_raw, p: FlashParams):
    """dS wrt pre-scale logits, including softcap chain rule; returns
    d(logits)/scale factor applied (i.e. gradient wrt q@k.T before *scale)."""
    ds = pmat * dp
    if p.softcap:
        ds = ds * (1.0 - (s_capped / p.softcap) ** 2)
    return ds * p.scale


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dsum_ref, dq_ref,
               dq_acc, *, p: FlashParams, nk: int, delta: int):
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    q_start = iq * p.block_q
    k_start = jk * p.block_k
    run = k_start < p.lk_valid
    if p.causal:
        run = jnp.logical_and(
            run, k_start <= q_start + (p.block_q - 1) + delta)
    if p.window is not None:
        run = jnp.logical_and(
            run, k_start + p.block_k - 1 >= q_start + delta - (p.window - 1))

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0]
        dsum = dsum_ref[0]

        s, mask, s_raw = _recompute_p(q, k, q_start, k_start, p, delta)
        shift = jnp.where(lse <= NEG_INF / 2, 0.0, lse)
        pmat = jnp.where(mask, jnp.exp(s - shift[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = _ds_from_dp(dp - dsum[:, None], pmat, s, s_raw, p)
        dq_acc[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(jk == nk - 1)
    def _finalize():
        dq_ref[0] = dq_acc[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dsum_ref,
                dk_ref, dv_ref, dk_acc, dv_acc,
                *, p: FlashParams, nq: int, delta: int):
    jk = pl.program_id(1)
    iq = pl.program_id(2)

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_start = iq * p.block_q
    k_start = jk * p.block_k
    run = k_start < p.lk_valid
    if p.causal:
        run = jnp.logical_and(
            run, k_start <= q_start + (p.block_q - 1) + delta)
    if p.window is not None:
        run = jnp.logical_and(
            run, k_start + p.block_k - 1 >= q_start + delta - (p.window - 1))

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0]
        dsum = dsum_ref[0]

        s, mask, s_raw = _recompute_p(q, k, q_start, k_start, p, delta)
        shift = jnp.where(lse <= NEG_INF / 2, 0.0, lse)
        pmat = jnp.where(mask, jnp.exp(s - shift[:, None]), 0.0)
        dv_acc[...] += jax.lax.dot_general(
            pmat, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = _ds_from_dp(dp - dsum[:, None], pmat, s, s_raw, p)
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(iq == nq - 1)
    def _finalize():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def _bwd(q, k, v, out, lse, do, p: FlashParams):
    bh, lq, d = q.shape
    _, lk, _ = k.shape
    nq = lq // p.block_q
    nk = lk // p.block_k
    delta = p.lk_valid - p.lq_valid
    dsum = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                   axis=-1)  # (BH, Lq)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, p=p, nk=nk, delta=delta),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, p.block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, p.block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, p.block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, p.block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, p.block_q), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, p.block_q), lambda b, i, j: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, p.block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, lq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((p.block_q, d), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=p.interpret,
    )(q, k, v, do, lse, dsum)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, p=p, nq=nq, delta=delta),
        grid=(bh, nk, nq),
        in_specs=[
            pl.BlockSpec((1, p.block_q, d), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, p.block_k, d), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, p.block_k, d), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, p.block_q, d), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, p.block_q), lambda b, j, i: (b, i)),
            pl.BlockSpec((1, p.block_q), lambda b, j, i: (b, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, p.block_k, d), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, p.block_k, d), lambda b, j, i: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, lk, d), k.dtype),
            jax.ShapeDtypeStruct((bh, lk, d), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((p.block_k, d), jnp.float32),
            pltpu.VMEM((p.block_k, d), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=p.interpret,
    )(q, k, v, do, lse, dsum)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# custom_vjp plumbing (head-folded layout)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _flash_folded(q, k, v, p: FlashParams):
    out, _ = _fwd(q, k, v, p)
    return out


def _flash_folded_with_lse(q, k, v, p: FlashParams):
    """Non-differentiable variant that also returns lse (for ring combine)."""
    return _fwd(q, k, v, p)


def _flash_fwd_rule(q, k, v, p: FlashParams):
    out, lse = _fwd(q, k, v, p)
    return out, (q, k, v, out, lse)


def _flash_bwd_rule(p: FlashParams, res, do):
    q, k, v, out, lse = res
    group = q.shape[0] // k.shape[0]
    if group > 1:
        # Expand KV across the query group for the dk/dv accumulation (the
        # grid's batch dim is "parallel", so racing accumulators across the
        # group is not allowed), then group-sum.
        k_exp = jnp.repeat(k, group, axis=0)
        v_exp = jnp.repeat(v, group, axis=0)
        dq, dk_exp, dv_exp = _bwd(q, k_exp, v_exp, out, lse, do, p)
        dk = dk_exp.reshape(k.shape[0], group, *k.shape[1:]).sum(axis=1)
        dv = dv_exp.reshape(v.shape[0], group, *v.shape[1:]).sum(axis=1)
        return dq, dk.astype(k.dtype), dv.astype(v.dtype)
    dq, dk, dv = _bwd(q, k, v, out, lse, do, p)
    return dq, dk, dv


_flash_folded.defvjp(_flash_fwd_rule, _flash_bwd_rule)
