"""Pallas TPU flash-attention kernel (forward + backward).

TPU-native adaptation of FlashAttention-2 for the LoongTrain reproduction:

* ``pl.pallas_call`` with explicit ``BlockSpec`` VMEM tiling; MXU-aligned
  (multiples-of-128) Q/K blocks; fp32 accumulators in VMEM scratch.
* Masking is driven by a *scalar-prefetch* band operand
  (``pltpu.PrefetchScalarGridSpec``): an int32 ``(5,)`` vector
  ``[q_off_lo, q_off_hi, k_off_lo, k_off_hi, kv_valid]`` carrying the
  piecewise logical-position offsets of ``ref.BandMask``.  The offsets may
  be traced (``lax.axis_index`` functions on the ring path), yet the
  bottom-right-aligned causal + sliding-window *block-skip* logic still
  runs inside the kernel: fully-masked K blocks are skipped via ``pl.when``
  on predicates computed from the prefetched scalars, so the compiled
  FLOPs of a causal call stay ~half of the dense product on every Double
  Ring step — not just the static diagonal.
* Sliding-window (local) masking, Gemma-style logit softcap, GQA via
  index-map head folding in *both* directions: the forward and dq kernels
  read KV block ``b // group``; the dk/dv kernel folds the query-head
  group into its (sequential) innermost grid dimension and accumulates the
  group-summed gradients in VMEM scratch, so replicated KV is never
  materialized anywhere.
* **Packed documents** (``FlashParams.packed``): a per-q-row int32
  doc-start table arrives as one more blocked ``(1, block_q)`` VMEM
  operand (shared by all folded heads of a sequence); keys below a row's
  document start are masked, and K blocks entirely below a q block's
  first-row doc start are *skipped* at grid level (``doc_skip``).  The
  full contract is written down in docs/KERNELS.md.

Validated on CPU with ``interpret=True`` against ``ref.py`` (see
``tests/test_kernels.py``).  On real TPUs set ``interpret=False``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import _logical_pos

NEG_INF = -1e30

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams.
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


class FlashParams(NamedTuple):
    """Static kernel configuration (hashable => usable as nondiff arg)."""
    causal: bool
    window: int | None
    softcap: float
    scale: float
    lq_valid: int          # number of real (unpadded) queries
    lk_valid: int          # attendable keys (kv_valid_len cut, else Lk)
    block_q: int
    block_k: int
    interpret: bool
    q_seg: int = 0         # physical row where the q hi-offset segment starts
    k_seg: int = 0         # (0 => unsplit: every row uses the hi offset)
    delta: int = 0         # default causal anchor: full Lk - Lq (the oracle
                           # anchors bottom-right at the full key length;
                           # kv_valid_len only cuts, it does not re-anchor)
    packed: bool = False   # packed documents: a per-q-row doc-start table
                           # (logical positions) arrives as one more blocked
                           # operand; keys before a row's doc start are
                           # masked (block-causal within each document)
    doc_skip: bool = True  # skip K blocks entirely below the q block's doc
                           # start (False: mask in-tile only — the dense-
                           # masked baseline the packing bench compares to)


def _default_band(p: FlashParams) -> jax.Array:
    """Band scalars for the classic bottom-right-aligned static mask."""
    return jnp.array([p.delta, p.delta, 0, 0, p.lk_valid], jnp.int32)


def _q_log(r, band_ref, p: FlashParams):
    """Logical sequence position of physical q row(s) ``r``."""
    return _logical_pos(r, band_ref[0], band_ref[1], p.q_seg)


def _k_log(c, band_ref, p: FlashParams):
    """Logical sequence position of physical k column(s) ``c``."""
    return _logical_pos(c, band_ref[2], band_ref[3], p.k_seg)


def _run_predicate(q_start, k_start, band_ref, p: FlashParams,
                   doc_ref=None):
    """Whole-block skip test.  Logical positions are nondecreasing in the
    physical index (the BandMask contract), so block extrema sit at the
    block edges even when a block straddles the segment boundary.

    Packed documents add a second skip direction: the doc-start table is
    nondecreasing in the physical q row (documents are contiguous logical
    intervals and rows are logically ordered), so the q block's smallest
    doc start sits at its first row; K blocks whose last logical position
    is below it are entirely cross-document and skipped."""
    run = k_start < band_ref[4]
    if p.causal:
        run = jnp.logical_and(
            run,
            _k_log(k_start, band_ref, p)
            <= _q_log(q_start + p.block_q - 1, band_ref, p))
    if p.window is not None:
        run = jnp.logical_and(
            run,
            _k_log(k_start + p.block_k - 1, band_ref, p)
            >= _q_log(q_start, band_ref, p) - (p.window - 1))
    if p.packed and p.doc_skip:
        run = jnp.logical_and(
            run,
            _k_log(k_start + p.block_k - 1, band_ref, p) >= doc_ref[0, 0])
    return run


def _tile_mask(q_start, k_start, band_ref, p: FlashParams, doc_ref=None):
    """Elementwise (block_q, block_k) visibility mask."""
    qi = q_start + jax.lax.broadcasted_iota(
        jnp.int32, (p.block_q, p.block_k), 0)
    kj = k_start + jax.lax.broadcasted_iota(
        jnp.int32, (p.block_q, p.block_k), 1)
    mask = kj < band_ref[4]
    if p.causal or p.window is not None:
        q_log = _q_log(qi, band_ref, p)
        k_log = _k_log(kj, band_ref, p)
        if p.causal:
            mask &= k_log <= q_log
        if p.packed:
            mask &= k_log >= doc_ref[0][:, None]
        if p.window is not None:
            mask &= k_log >= q_log - (p.window - 1)
    return mask


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _fwd_kernel(band_ref, *refs, p: FlashParams, nk: int):
    if p.packed:
        q_ref, k_ref, v_ref, doc_ref = refs[:4]
    else:
        (q_ref, k_ref, v_ref), doc_ref = refs[:3], None
    o_ref, lse_ref, acc_ref, m_ref, l_ref = refs[-5:]
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * p.block_q
    k_start = jk * p.block_k

    @pl.when(_run_predicate(q_start, k_start, band_ref, p, doc_ref))
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * p.scale
        if p.softcap:
            s = p.softcap * jnp.tanh(s / p.softcap)

        mask = _tile_mask(q_start, k_start, band_ref, p, doc_ref)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        # fully-masked-so-far rows: keep shift at 0 to avoid exp(inf) traps
        shift = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        pmat = jnp.exp(s - shift[:, None])
        pmat = jnp.where(mask, pmat, 0.0)
        alpha = jnp.exp(jnp.where(m_prev <= NEG_INF / 2, NEG_INF,
                                  m_prev - shift))
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, alpha)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(pmat, axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            pmat, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(jk == nk - 1)
    def _finalize():
        l = l_ref[...]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)
        m = m_ref[...]
        shift = jnp.where(m <= NEG_INF / 2, 0.0, m)
        lse_ref[0] = jnp.where(l == 0.0, NEG_INF, shift + jnp.log(l_safe))


def _fwd(q, k, v, p: FlashParams, band=None, doc=None):
    """q: (B*Hq, Lq, D); k/v: (B*Hkv, Lk, D), heads folded major-to-minor.

    GQA is handled in the K/V index maps (kv row = q row // group), so the
    replicated KV is never materialized.  ``band``: optional int32 (5,)
    scalar-prefetch vector (see module docstring); defaults to the static
    bottom-right band.  ``doc``: optional (B, Lq) int32 per-row doc-start
    table (``p.packed`` must be set) — blocked over q, shared across the
    folded heads of each sequence.  Returns out (BH, Lq, D),
    lse (BH, Lq) fp32.
    """
    bh, lq, d = q.shape
    bhkv, lk, _ = k.shape
    assert bh % bhkv == 0, (bh, bhkv)
    assert (doc is not None) == p.packed, (doc is None, p.packed)
    group = bh // bhkv
    nq = lq // p.block_q
    nk = lk // p.block_k
    if band is None:
        band = _default_band(p)

    kernel = functools.partial(_fwd_kernel, p=p, nk=nk)
    in_specs = [
        pl.BlockSpec((1, p.block_q, d), lambda b, i, j, s: (b, i, 0)),
        pl.BlockSpec((1, p.block_k, d),
                     lambda b, i, j, s: (b // group, j, 0)),
        pl.BlockSpec((1, p.block_k, d),
                     lambda b, i, j, s: (b // group, j, 0)),
    ]
    operands = (q, k, v)
    if p.packed:
        q_mult = bh // doc.shape[0]
        in_specs.append(pl.BlockSpec(
            (1, p.block_q), lambda b, i, j, s: (b // q_mult, i)))
        operands = (q, k, v, doc)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bh, nq, nk),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, p.block_q, d), lambda b, i, j, s: (b, i, 0)),
            pl.BlockSpec((1, p.block_q), lambda b, i, j, s: (b, i)),
        ],
        scratch_shapes=[
            pltpu.VMEM((p.block_q, d), jnp.float32),
            pltpu.VMEM((p.block_q,), jnp.float32),
            pltpu.VMEM((p.block_q,), jnp.float32),
        ],
    )
    out, lse = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bh, lq, d), q.dtype),
            jax.ShapeDtypeStruct((bh, lq), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=p.interpret,
    )(band, *operands)
    return out, lse


# ---------------------------------------------------------------------------
# Backward
# ---------------------------------------------------------------------------

def _recompute_p(q, k, q_start, k_start, band_ref, p: FlashParams,
                 doc_ref=None):
    """Recompute softcapped+masked scores; returns (s_capped, mask, s_raw)."""
    s_raw = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * p.scale
    s = p.softcap * jnp.tanh(s_raw / p.softcap) if p.softcap else s_raw
    mask = _tile_mask(q_start, k_start, band_ref, p, doc_ref)
    return s, mask, s_raw


def _ds_from_dp(dp, pmat, s_capped, s_raw, p: FlashParams):
    """dS wrt pre-scale logits, including softcap chain rule; returns
    d(logits)/scale factor applied (i.e. gradient wrt q@k.T before *scale)."""
    ds = pmat * dp
    if p.softcap:
        ds = ds * (1.0 - (s_capped / p.softcap) ** 2)
    return ds * p.scale


def _dq_kernel(band_ref, *refs, p: FlashParams, nk: int):
    if p.packed:
        q_ref, k_ref, v_ref, do_ref, lse_ref, dsum_ref, doc_ref = refs[:7]
    else:
        q_ref, k_ref, v_ref, do_ref, lse_ref, dsum_ref = refs[:6]
        doc_ref = None
    dq_ref, dq_acc = refs[-2:]
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    q_start = iq * p.block_q
    k_start = jk * p.block_k

    @pl.when(_run_predicate(q_start, k_start, band_ref, p, doc_ref))
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0]
        dsum = dsum_ref[0]

        s, mask, s_raw = _recompute_p(q, k, q_start, k_start, band_ref, p,
                                      doc_ref)
        shift = jnp.where(lse <= NEG_INF / 2, 0.0, lse)
        pmat = jnp.where(mask, jnp.exp(s - shift[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = _ds_from_dp(dp - dsum[:, None], pmat, s, s_raw, p)
        dq_acc[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(jk == nk - 1)
    def _finalize():
        dq_ref[0] = dq_acc[...].astype(dq_ref.dtype)


def _dkv_kernel(band_ref, *refs, p: FlashParams, nq: int, group: int):
    """dk/dv for one KV head.  The innermost (sequential) grid dimension
    runs over ``group * nq`` steps — all q blocks of every query head in
    this KV head's group — so the group-summed gradients accumulate in the
    VMEM scratch without ever materializing group-expanded K/V."""
    if p.packed:
        q_ref, k_ref, v_ref, do_ref, lse_ref, dsum_ref, doc_ref = refs[:7]
    else:
        q_ref, k_ref, v_ref, do_ref, lse_ref, dsum_ref = refs[:6]
        doc_ref = None
    dk_ref, dv_ref, dk_acc, dv_acc = refs[-4:]
    jk = pl.program_id(1)
    ig = pl.program_id(2)            # ig = g * nq + iq

    @pl.when(ig == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_start = jax.lax.rem(ig, nq) * p.block_q
    k_start = jk * p.block_k

    @pl.when(_run_predicate(q_start, k_start, band_ref, p, doc_ref))
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0]
        dsum = dsum_ref[0]

        s, mask, s_raw = _recompute_p(q, k, q_start, k_start, band_ref, p,
                                      doc_ref)
        shift = jnp.where(lse <= NEG_INF / 2, 0.0, lse)
        pmat = jnp.where(mask, jnp.exp(s - shift[:, None]), 0.0)
        dv_acc[...] += jax.lax.dot_general(
            pmat, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = _ds_from_dp(dp - dsum[:, None], pmat, s, s_raw, p)
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ig == group * nq - 1)
    def _finalize():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def _bwd(q, k, v, out, lse, do, p: FlashParams, band=None, doc=None):
    """Backward in the folded layout.  k/v may have fewer (KV) heads than
    q (GQA); dk/dv come back at the KV head count, group-summed."""
    bh, lq, d = q.shape
    bhkv, lk, _ = k.shape
    assert bh % bhkv == 0, (bh, bhkv)
    assert (doc is not None) == p.packed, (doc is None, p.packed)
    group = bh // bhkv
    nq = lq // p.block_q
    nk = lk // p.block_k
    if band is None:
        band = _default_band(p)
    dsum = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                   axis=-1)  # (BH, Lq)

    dq_in_specs = [
        pl.BlockSpec((1, p.block_q, d), lambda b, i, j, s: (b, i, 0)),
        pl.BlockSpec((1, p.block_k, d),
                     lambda b, i, j, s: (b // group, j, 0)),
        pl.BlockSpec((1, p.block_k, d),
                     lambda b, i, j, s: (b // group, j, 0)),
        pl.BlockSpec((1, p.block_q, d), lambda b, i, j, s: (b, i, 0)),
        pl.BlockSpec((1, p.block_q), lambda b, i, j, s: (b, i)),
        pl.BlockSpec((1, p.block_q), lambda b, i, j, s: (b, i)),
    ]
    operands = (q, k, v, do, lse, dsum)
    if p.packed:
        q_mult = bh // doc.shape[0]
        dq_in_specs.append(pl.BlockSpec(
            (1, p.block_q), lambda b, i, j, s: (b // q_mult, i)))
        operands = operands + (doc,)
    dq_grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bh, nq, nk),
        in_specs=dq_in_specs,
        out_specs=pl.BlockSpec((1, p.block_q, d),
                               lambda b, i, j, s: (b, i, 0)),
        scratch_shapes=[pltpu.VMEM((p.block_q, d), jnp.float32)],
    )
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, p=p, nk=nk),
        grid_spec=dq_grid_spec,
        out_shape=jax.ShapeDtypeStruct((bh, lq, d), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=p.interpret,
    )(band, *operands)

    # Query-side operands walk b*group + ig//nq: for a fixed KV head, the
    # sequential dimension visits each group member's q blocks in turn.
    dkv_in_specs = [
        pl.BlockSpec((1, p.block_q, d),
                     lambda b, j, g, s: (b * group + g // nq,
                                         g % nq, 0)),
        pl.BlockSpec((1, p.block_k, d), lambda b, j, g, s: (b, j, 0)),
        pl.BlockSpec((1, p.block_k, d), lambda b, j, g, s: (b, j, 0)),
        pl.BlockSpec((1, p.block_q, d),
                     lambda b, j, g, s: (b * group + g // nq,
                                         g % nq, 0)),
        pl.BlockSpec((1, p.block_q),
                     lambda b, j, g, s: (b * group + g // nq, g % nq)),
        pl.BlockSpec((1, p.block_q),
                     lambda b, j, g, s: (b * group + g // nq, g % nq)),
    ]
    if p.packed:
        q_mult = bh // doc.shape[0]
        dkv_in_specs.append(pl.BlockSpec(
            (1, p.block_q),
            lambda b, j, g, s: ((b * group + g // nq) // q_mult, g % nq)))
    dkv_grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bhkv, nk, group * nq),
        in_specs=dkv_in_specs,
        out_specs=[
            pl.BlockSpec((1, p.block_k, d), lambda b, j, g, s: (b, j, 0)),
            pl.BlockSpec((1, p.block_k, d), lambda b, j, g, s: (b, j, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((p.block_k, d), jnp.float32),
            pltpu.VMEM((p.block_k, d), jnp.float32),
        ],
    )
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, p=p, nq=nq, group=group),
        grid_spec=dkv_grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bhkv, lk, d), k.dtype),
            jax.ShapeDtypeStruct((bhkv, lk, d), v.dtype),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=p.interpret,
    )(band, *operands)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# custom_vjp plumbing (head-folded layout)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _flash_folded(q, k, v, p: FlashParams):
    out, _ = _fwd(q, k, v, p)
    return out


def _flash_folded_with_lse(q, k, v, p: FlashParams):
    """Non-differentiable variant that also returns lse (for ring combine)."""
    return _fwd(q, k, v, p)


def _flash_fwd_rule(q, k, v, p: FlashParams):
    out, lse = _fwd(q, k, v, p)
    return out, (q, k, v, out, lse)


def _flash_bwd_rule(p: FlashParams, res, do):
    q, k, v, out, lse = res
    # GQA dk/dv are group-summed inside the dkv kernel (the query group is
    # folded into its sequential grid dimension) — no KV expansion here.
    return _bwd(q, k, v, out, lse, do, p)


_flash_folded.defvjp(_flash_fwd_rule, _flash_bwd_rule)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def _flash_folded_doc(q, k, v, doc, p: FlashParams):
    """Packed-document variant: ``doc`` is the (B, Lq_pad) int32 per-row
    doc-start table (integer data — its cotangent is float0)."""
    out, _ = _fwd(q, k, v, p, doc=doc)
    return out


def _flash_doc_fwd_rule(q, k, v, doc, p: FlashParams):
    out, lse = _fwd(q, k, v, p, doc=doc)
    return out, (q, k, v, doc, out, lse)


def _flash_doc_bwd_rule(p: FlashParams, res, do):
    q, k, v, doc, out, lse = res
    dq, dk, dv = _bwd(q, k, v, out, lse, do, p, doc=doc)
    return dq, dk, dv, np.zeros(doc.shape, jax.dtypes.float0)


_flash_folded_doc.defvjp(_flash_doc_fwd_rule, _flash_doc_bwd_rule)
