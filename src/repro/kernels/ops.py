"""Public attention ops: impl dispatch, layout/padding plumbing.

Three entry points:

* ``flash_attention``          — differentiable single-call attention
                                 (custom_vjp Pallas path or jnp ref path).
* ``flash_fwd_chunk``          — non-differentiable (out, lse) for one KV
                                 chunk; the ring-attention building block.
* ``flash_bwd_chunk``          — chunk backward given global (out, lse).

Layout everywhere: ``q (B, Lq, Hq, D)``, ``k/v (B, Lk, Hkv, D)``.

``impl``:
* ``"auto"``             — Pallas on TPU, ref elsewhere (CPU dry-run/compile
                            keeps attention as plain einsums XLA can cost).
* ``"pallas"``           — compiled Pallas kernel (TPU).
* ``"pallas_interpret"`` — Pallas kernel body interpreted on CPU (tests).
* ``"ref"``              — pure-jnp oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as ref_mod
from repro.kernels.flash_attention import (FlashParams, _flash_folded,
                                           _fwd, _bwd)

NEG_INF = ref_mod.NEG_INF


def resolve_impl(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "flashref"
    return impl


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _fold_pad(x, block_l: int, d_pad: int):
    """(B, L, H, D) -> (B*H, L_pad, D_pad)."""
    b, l, h, d = x.shape
    x = jnp.transpose(x, (0, 2, 1, 3)).reshape(b * h, l, d)
    l_pad = _round_up(l, block_l)
    if l_pad != l or d_pad != d:
        x = jnp.pad(x, ((0, 0), (0, l_pad - l), (0, d_pad - d)))
    return x


def _unfold(x, b: int, h: int, l: int, d: int):
    """(B*H, L_pad, D_pad) -> (B, L, H, D)."""
    x = x[:, :l, :d].reshape(b, h, l, d)
    return jnp.transpose(x, (0, 2, 1, 3))


def _make_params(q, k, *, causal, window, softcap, scale, kv_valid_len,
                 block_q, block_k, interpret):
    _, lq, _, d = q.shape
    _, lk, _, _ = k.shape
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    bq = min(block_q, _round_up(lq, 8))
    bk = min(block_k, _round_up(lk, 8))
    lk_valid = lk if kv_valid_len is None else kv_valid_len
    return FlashParams(causal=causal, window=window, softcap=float(softcap),
                       scale=float(scale), lq_valid=int(lq),
                       lk_valid=int(lk_valid),
                       block_q=bq, block_k=bk, interpret=interpret), bq, bk


def flash_attention(q, k, v, *, causal: bool = False,
                    window: int | None = None, softcap: float = 0.0,
                    scale: float | None = None,
                    kv_valid_len: int | None = None,
                    impl: str = "auto",
                    block_q: int = 128, block_k: int = 128):
    """Differentiable attention.  Returns out (B, Lq, Hq, D)."""
    impl = resolve_impl(impl)
    if impl == "flashref":
        out, _ = ref_mod.attention_ref_chunked(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, kv_valid_len=kv_valid_len)
        return out
    if impl == "ref":
        out, _ = ref_mod.attention_ref(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, kv_valid_len=kv_valid_len)
        return out
    interpret = impl == "pallas_interpret"
    b, lq, hq, d = q.shape
    _, lk, hkv, _ = k.shape
    p, bq, bk = _make_params(q, k, causal=causal, window=window,
                             softcap=softcap, scale=scale,
                             kv_valid_len=kv_valid_len, block_q=block_q,
                             block_k=block_k, interpret=interpret)
    d_pad = _round_up(d, 128)
    qf = _fold_pad(q, bq, d_pad)
    kf = _fold_pad(k, bk, d_pad)
    vf = _fold_pad(v, bk, d_pad)
    out = _flash_folded(qf, kf, vf, p)
    return _unfold(out, b, hq, lq, d)


def flash_fwd_chunk(q, k, v, *, causal: bool = False,
                    window: int | None = None, softcap: float = 0.0,
                    scale: float | None = None,
                    kv_valid_len: int | None = None,
                    mask_offset=None,
                    impl: str = "auto",
                    block_q: int = 128, block_k: int = 128):
    """Non-differentiable (out, lse) — ring / decode building block.

    out (B, Lq, Hq, D);  lse (B, Hq, Lq) fp32.

    ``mask_offset`` (possibly traced) forces the jnp path — the Pallas
    kernel's block-skip logic needs static offsets.
    """
    impl = resolve_impl(impl)
    if mask_offset is not None and impl == "pallas":
        impl = "flashref"
    if impl == "flashref":
        return ref_mod.attention_ref_chunked(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, kv_valid_len=kv_valid_len, mask_offset=mask_offset)
    if impl == "ref":
        return ref_mod.attention_ref(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, kv_valid_len=kv_valid_len, mask_offset=mask_offset)
    interpret = impl == "pallas_interpret"
    b, lq, hq, d = q.shape
    _, lk, hkv, _ = k.shape
    p, bq, bk = _make_params(q, k, causal=causal, window=window,
                             softcap=softcap, scale=scale,
                             kv_valid_len=kv_valid_len, block_q=block_q,
                             block_k=block_k, interpret=interpret)
    d_pad = _round_up(d, 128)
    qf = _fold_pad(q, bq, d_pad)
    kf = _fold_pad(k, bk, d_pad)
    vf = _fold_pad(v, bk, d_pad)
    out, lse = _fwd(qf, kf, vf, p)
    out = _unfold(out, b, hq, lq, d)
    lse = lse[:, :lq].reshape(b, hq, lq)
    return out, lse


def flash_bwd_chunk(q, k, v, out, lse, do, *, causal: bool = False,
                    window: int | None = None, softcap: float = 0.0,
                    scale: float | None = None,
                    kv_valid_len: int | None = None,
                    mask_offset=None,
                    impl: str = "auto",
                    block_q: int = 128, block_k: int = 128):
    """Chunk backward given global (out, lse).  Returns (dq, dk, dv)."""
    impl = resolve_impl(impl)
    if mask_offset is not None and impl == "pallas":
        impl = "flashref"
    if impl == "flashref":
        return ref_mod.attention_bwd_ref_chunked(
            q, k, v, out, lse, do, causal=causal, window=window,
            softcap=softcap, scale=scale, kv_valid_len=kv_valid_len,
            mask_offset=mask_offset)
    if impl == "ref":
        return ref_mod.attention_bwd_ref(
            q, k, v, out, lse, do, causal=causal, window=window,
            softcap=softcap, scale=scale, kv_valid_len=kv_valid_len,
            mask_offset=mask_offset)
    interpret = impl == "pallas_interpret"
    b, lq, hq, d = q.shape
    _, lk, hkv, _ = k.shape
    p, bq, bk = _make_params(q, k, causal=causal, window=window,
                             softcap=softcap, scale=scale,
                             kv_valid_len=kv_valid_len, block_q=block_q,
                             block_k=block_k, interpret=interpret)
    d_pad = _round_up(d, 128)
    group = hq // hkv
    qf = _fold_pad(q, bq, d_pad)
    kf = _fold_pad(jnp.repeat(k, group, axis=2) if group > 1 else k,
                   bk, d_pad)
    vf = _fold_pad(jnp.repeat(v, group, axis=2) if group > 1 else v,
                   bk, d_pad)
    outf = _fold_pad(out, bq, d_pad)
    dof = _fold_pad(do, bq, d_pad)
    lq_pad = qf.shape[1]
    lsef = lse.reshape(b * hq, lq)
    if lq_pad != lq:
        lsef = jnp.pad(lsef, ((0, 0), (0, lq_pad - lq)))
    dqf, dkf, dvf = _bwd(qf, kf, vf, outf, lsef, dof, p)
    dq = _unfold(dqf, b, hq, lq, d)
    dk_exp = _unfold(dkf, b, hq, lk, d)
    dv_exp = _unfold(dvf, b, hq, lk, d)
    if group > 1:
        dk = dk_exp.reshape(b, lk, hkv, group, d).sum(axis=3).astype(k.dtype)
        dv = dv_exp.reshape(b, lk, hkv, group, d).sum(axis=3).astype(v.dtype)
    else:
        dk, dv = dk_exp, dv_exp
    return dq, dk, dv
