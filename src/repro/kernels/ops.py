"""Public attention ops: impl dispatch, layout/padding plumbing.

Three entry points:

* ``flash_attention``          — differentiable single-call attention
                                 (custom_vjp Pallas path or jnp ref path).
* ``flash_fwd_chunk``          — non-differentiable (out, lse) for one KV
                                 chunk; the ring-attention building block.
* ``flash_bwd_chunk``          — chunk backward given global (out, lse).

Layout everywhere: ``q (B, Lq, Hq, D)``, ``k/v (B, Lk, Hkv, D)``.

Impl dispatch
-------------
``impl`` picks the compute path; ``resolve_impl`` maps ``"auto"`` to the
backend default:

================== =========================================================
``impl``           what runs
================== =========================================================
``"auto"``         ``"pallas"`` on TPU; ``"flashref"`` elsewhere (CPU
                   dry-run/compile keeps attention as plain einsums XLA
                   can cost).
``"pallas"``       compiled Pallas kernel (TPU).  Traced ``mask_offset`` /
                   ``band`` values ride in as scalar-prefetch operands, so
                   **every Double-Ring step stays on the fused kernel** —
                   there is no downgrade for dynamic offsets.
``"pallas_interpret"`` same kernels, interpreted on CPU (tests/benches).
``"flashref"``     q-chunked pure-jnp oracle (flash memory semantics).
``"ref"``          dense pure-jnp oracle.
================== =========================================================

Masking
-------
``mask_offset`` (scalar, possibly traced) sets the bottom-right band
``kj <= qi + mask_offset``; ``band`` (a ``ref.BandMask``) generalizes it to
the segmented zigzag layout, letting one kernel call cover any ring-step
pair (diagonal, j<i, j>i).  Both are honored identically by every impl.

GQA
---
The Pallas forward and dq kernels fold the head group into the K/V index
maps; the dk/dv kernel folds it into its sequential grid dimension and
group-sums in VMEM scratch.  No path materializes ``group×``-expanded K/V
or gradients.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as ref_mod
from repro.kernels.flash_attention import (FlashParams, _flash_folded,
                                           _flash_folded_doc, _fwd, _bwd)
from repro.kernels.ref import BandMask

NEG_INF = ref_mod.NEG_INF

#: doc-start sentinel for padded q rows: larger than any logical position,
#: so padding rows see no keys (their outputs are dropped by _unfold).
DOC_PAD = 1 << 30


def resolve_impl(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "flashref"
    return impl


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _fold_pad(x, block_l: int, d_pad: int):
    """(B, L, H, D) -> (B*H, L_pad, D_pad)."""
    b, l, h, d = x.shape
    x = jnp.transpose(x, (0, 2, 1, 3)).reshape(b * h, l, d)
    l_pad = _round_up(l, block_l)
    if l_pad != l or d_pad != d:
        x = jnp.pad(x, ((0, 0), (0, l_pad - l), (0, d_pad - d)))
    return x


def _unfold(x, b: int, h: int, l: int, d: int):
    """(B*H, L_pad, D_pad) -> (B, L, H, D)."""
    x = x[:, :l, :d].reshape(b, h, l, d)
    return jnp.transpose(x, (0, 2, 1, 3))


def _make_params(q, k, *, causal, window, softcap, scale, kv_valid_len,
                 block_q, block_k, interpret, q_seg=0, k_seg=0,
                 packed=False, doc_skip=True):
    _, lq, _, d = q.shape
    _, lk, _, _ = k.shape
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    bq = min(block_q, _round_up(lq, 8))
    bk = min(block_k, _round_up(lk, 8))
    lk_valid = lk if kv_valid_len is None else kv_valid_len
    return FlashParams(causal=causal, window=window, softcap=float(softcap),
                       scale=float(scale), lq_valid=int(lq),
                       lk_valid=int(lk_valid),
                       block_q=bq, block_k=bk, interpret=interpret,
                       q_seg=int(q_seg), k_seg=int(k_seg),
                       delta=int(lk - lq), packed=bool(packed),
                       doc_skip=bool(doc_skip)), bq, bk


def _pad_doc(q_doc_start, lq: int, block_q: int):
    """(B, Lq) int32 doc-start table, q rows padded with ``DOC_PAD`` (the
    padded rows attend nothing; their outputs are dropped)."""
    doc = jnp.asarray(q_doc_start, jnp.int32)
    assert doc.ndim == 2 and doc.shape[1] == lq, (doc.shape, lq)
    lq_pad = _round_up(lq, block_q)
    if lq_pad != lq:
        doc = jnp.pad(doc, ((0, 0), (0, lq_pad - lq)),
                      constant_values=DOC_PAD)
    return doc


def _band_scalars(band, mask_offset, lq: int, lk: int, kv_valid_len,
                  *, causal, window):
    """(int32 (5,) scalar-prefetch vector, q_seg, k_seg).

    Offsets are in *unpadded* physical coordinates — padding appends rows,
    so real rows keep their indices; padded keys are cut by ``kv_valid``.
    """
    if band is not None and not causal and window is None:
        raise ValueError("band only shifts the causal/window band anchors; "
                         "passing one with causal=False and window=None "
                         "would be silently ignored")
    if band is None:
        off = (lk - lq) if mask_offset is None else mask_offset
        band = BandMask.uniform(off)
    kv_valid = lk if kv_valid_len is None else kv_valid_len
    scalars = jnp.stack([jnp.asarray(x, jnp.int32) for x in
                         (band.q_off_lo, band.q_off_hi,
                          band.k_off_lo, band.k_off_hi, kv_valid)])
    return scalars, band.q_seg, band.k_seg


def flash_attention(q, k, v, *, causal: bool = False,
                    window: int | None = None, softcap: float = 0.0,
                    scale: float | None = None,
                    kv_valid_len: int | None = None,
                    q_doc_start=None, doc_skip: bool = True,
                    impl: str = "auto",
                    block_q: int = 128, block_k: int = 128):
    """Differentiable attention.  Returns out (B, Lq, Hq, D).

    ``q_doc_start``: packed-document block-causal masking — a (B, Lq)
    int32 table of each q row's logical document start (see ref.py).
    Requires ``causal=True``; on the Pallas path, K blocks entirely below
    a q block's doc start are *skipped* (``doc_skip=False`` keeps the
    element-wise mask but disables the skip — the dense-masked baseline
    the packing bench measures against).
    """
    impl = resolve_impl(impl)
    if q_doc_start is not None and not causal:
        raise ValueError("q_doc_start requires causal=True")
    if impl == "flashref":
        out, _ = ref_mod.attention_ref_chunked(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, kv_valid_len=kv_valid_len,
            q_doc_start=q_doc_start)
        return out
    if impl == "ref":
        out, _ = ref_mod.attention_ref(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, kv_valid_len=kv_valid_len,
            q_doc_start=q_doc_start)
        return out
    interpret = impl == "pallas_interpret"
    b, lq, hq, d = q.shape
    _, lk, hkv, _ = k.shape
    p, bq, bk = _make_params(q, k, causal=causal, window=window,
                             softcap=softcap, scale=scale,
                             kv_valid_len=kv_valid_len, block_q=block_q,
                             block_k=block_k, interpret=interpret,
                             packed=q_doc_start is not None,
                             doc_skip=doc_skip)
    d_pad = _round_up(d, 128)
    qf = _fold_pad(q, bq, d_pad)
    kf = _fold_pad(k, bk, d_pad)
    vf = _fold_pad(v, bk, d_pad)
    if q_doc_start is not None:
        doc = _pad_doc(q_doc_start, lq, bq)
        out = _flash_folded_doc(qf, kf, vf, doc, p)
    else:
        out = _flash_folded(qf, kf, vf, p)
    return _unfold(out, b, hq, lq, d)


def flash_fwd_chunk(q, k, v, *, causal: bool = False,
                    window: int | None = None, softcap: float = 0.0,
                    scale: float | None = None,
                    kv_valid_len: int | None = None, kv_start=None,
                    mask_offset=None, band: BandMask | None = None,
                    q_doc_start=None, doc_skip: bool = True,
                    impl: str = "auto",
                    block_q: int = 128, block_k: int = 128):
    """Non-differentiable (out, lse) — ring / decode building block.

    out (B, Lq, Hq, D);  lse (B, Hq, Lq) fp32.

    ``mask_offset`` / ``band`` may be traced: the Pallas path threads them
    into the kernel as scalar-prefetch operands and keeps its block-skip
    logic (no downgrade to the jnp path).  ``q_doc_start`` (packed
    documents, (B, Lq) int32 per-row doc starts) rides in as a blocked
    VMEM operand the same way — cross-document K blocks are skipped
    unless ``doc_skip=False``.  Per-request ``(B,)`` ragged offsets
    (``mask_offset`` / ``kv_valid_len`` / ``kv_start`` — the
    continuous-batching decode case) are ref-path only.
    """
    impl = resolve_impl(impl)
    if q_doc_start is not None and not causal:
        raise ValueError("q_doc_start requires causal=True")
    ragged = any(isinstance(x, jax.Array) and x.ndim >= 1
                 for x in (mask_offset, kv_valid_len, kv_start))
    if kv_start is not None or ragged:
        if impl not in ("ref", "flashref"):
            raise NotImplementedError(
                "per-request ragged masks (kv_start / batched offsets) are "
                f"only lowered on the ref paths, got impl={impl!r}")
    if impl == "flashref":
        return ref_mod.attention_ref_chunked(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, kv_valid_len=kv_valid_len, kv_start=kv_start,
            mask_offset=mask_offset, band=band, q_doc_start=q_doc_start)
    if impl == "ref":
        return ref_mod.attention_ref(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, kv_valid_len=kv_valid_len, kv_start=kv_start,
            mask_offset=mask_offset, band=band, q_doc_start=q_doc_start)
    interpret = impl == "pallas_interpret"
    b, lq, hq, d = q.shape
    _, lk, hkv, _ = k.shape
    scalars, q_seg, k_seg = _band_scalars(band, mask_offset, lq, lk,
                                          kv_valid_len, causal=causal,
                                          window=window)
    p, bq, bk = _make_params(q, k, causal=causal, window=window,
                             softcap=softcap, scale=scale,
                             kv_valid_len=None, block_q=block_q,
                             block_k=block_k, interpret=interpret,
                             q_seg=q_seg, k_seg=k_seg,
                             packed=q_doc_start is not None,
                             doc_skip=doc_skip)
    d_pad = _round_up(d, 128)
    qf = _fold_pad(q, bq, d_pad)
    kf = _fold_pad(k, bk, d_pad)
    vf = _fold_pad(v, bk, d_pad)
    doc = None if q_doc_start is None else _pad_doc(q_doc_start, lq, bq)
    out, lse = _fwd(qf, kf, vf, p, band=scalars, doc=doc)
    out = _unfold(out, b, hq, lq, d)
    lse = lse[:, :lq].reshape(b, hq, lq)
    return out, lse


def flash_bwd_chunk(q, k, v, out, lse, do, *, causal: bool = False,
                    window: int | None = None, softcap: float = 0.0,
                    scale: float | None = None,
                    kv_valid_len: int | None = None,
                    mask_offset=None, band: BandMask | None = None,
                    q_doc_start=None, doc_skip: bool = True,
                    impl: str = "auto",
                    block_q: int = 128, block_k: int = 128):
    """Chunk backward given global (out, lse).  Returns (dq, dk, dv).

    GQA gradients are group-summed inside the dk/dv kernel — no
    ``group×``-expanded K/V is allocated on any path.
    """
    impl = resolve_impl(impl)
    if q_doc_start is not None and not causal:
        raise ValueError("q_doc_start requires causal=True")
    if impl == "flashref":
        return ref_mod.attention_bwd_ref_chunked(
            q, k, v, out, lse, do, causal=causal, window=window,
            softcap=softcap, scale=scale, kv_valid_len=kv_valid_len,
            mask_offset=mask_offset, band=band, q_doc_start=q_doc_start)
    if impl == "ref":
        return ref_mod.attention_bwd_ref(
            q, k, v, out, lse, do, causal=causal, window=window,
            softcap=softcap, scale=scale, kv_valid_len=kv_valid_len,
            mask_offset=mask_offset, band=band, q_doc_start=q_doc_start)
    interpret = impl == "pallas_interpret"
    b, lq, hq, d = q.shape
    _, lk, hkv, _ = k.shape
    scalars, q_seg, k_seg = _band_scalars(band, mask_offset, lq, lk,
                                          kv_valid_len, causal=causal,
                                          window=window)
    p, bq, bk = _make_params(q, k, causal=causal, window=window,
                             softcap=softcap, scale=scale,
                             kv_valid_len=None, block_q=block_q,
                             block_k=block_k, interpret=interpret,
                             q_seg=q_seg, k_seg=k_seg,
                             packed=q_doc_start is not None,
                             doc_skip=doc_skip)
    d_pad = _round_up(d, 128)
    qf = _fold_pad(q, bq, d_pad)
    kf = _fold_pad(k, bk, d_pad)
    vf = _fold_pad(v, bk, d_pad)
    outf = _fold_pad(out, bq, d_pad)
    dof = _fold_pad(do, bq, d_pad)
    lq_pad = qf.shape[1]
    lsef = lse.reshape(b * hq, lq)
    if lq_pad != lq:
        lsef = jnp.pad(lsef, ((0, 0), (0, lq_pad - lq)))
    doc = None if q_doc_start is None else _pad_doc(q_doc_start, lq, bq)
    dqf, dkf, dvf = _bwd(qf, kf, vf, outf, lsef, dof, p, band=scalars,
                         doc=doc)
    dq = _unfold(dqf, b, hq, lq, d)
    dk = _unfold(dkf, b, hkv, lk, d).astype(k.dtype)
    dv = _unfold(dvf, b, hkv, lk, d).astype(v.dtype)
    return dq, dk, dv
