"""Pure-jnp oracle for blockwise (flash) attention.

This is the correctness reference for the Pallas TPU kernel
(`flash_attention.py`) and the building block the distributed 2D-Attention
tests compare against.  Everything computes in fp32 regardless of input
dtype.

Conventions
-----------
* Layout: ``q: (B, Lq, Hq, D)``, ``k/v: (B, Lk, Hkv, D)`` with
  ``Hq % Hkv == 0`` (GQA).
* ``causal=True`` means *bottom-right aligned* causal: query row ``i`` may
  attend key column ``j`` iff ``j <= i + (Lk - Lq)``.  For ``Lq == Lk`` this
  is the standard causal mask; for ring-attention partial blocks it encodes
  "this KV chunk ends where the Q chunk ends".
* ``window`` (sliding-window / local attention): additionally require
  ``j >= i + (Lk - Lq) - window + 1``.
* ``softcap``: Gemma-2 style logit soft-capping ``cap * tanh(s / cap)``.
* Returns ``(out, lse)`` where ``lse[b, h, i] = logsumexp_j(scores)`` in
  fp32; rows with no visible key get ``lse = -inf`` and ``out = 0``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


class BandMask(NamedTuple):
    """Piecewise-affine logical-position mask — the scalar contract shared
    by the oracle and the Pallas kernels (where the four offsets ride in as
    scalar-prefetch operands).

    Physical row ``r`` of the Q chunk has *logical* sequence position
    ``r + q_off_lo`` when ``r < q_seg`` else ``r + q_off_hi`` (same for K
    columns with ``k_*``).  Logical positions must be nondecreasing in the
    physical index — true for both layouts we use:

    * **uniform** — one offset per side; encodes the classic
      ``kj <= qi + mask_offset`` bottom-right band.
    * **zigzag** — rank ``i`` owns logical chunks ``(i, 2cp-1-i)``; the two
      halves of the physical chunk get distinct offsets, which lets a single
      kernel call evaluate any ring-step pair (diagonal, j<i, j>i) without
      ``lax.cond`` branches.

    Offsets may be traced scalars (``lax.axis_index`` functions); the
    segment boundaries are static ints.
    """
    q_off_lo: jax.Array | int
    q_off_hi: jax.Array | int
    k_off_lo: jax.Array | int
    k_off_hi: jax.Array | int
    q_seg: int
    k_seg: int

    @classmethod
    def uniform(cls, offset) -> "BandMask":
        """``kj <= qi + offset`` (and window band) — both sides unsplit."""
        return cls(offset, offset, 0, 0, 0, 0)

    @classmethod
    def zigzag(cls, i, j, c: int, cp: int) -> "BandMask":
        """Local q owns logical chunks (i, 2cp-1-i) of size ``c``; visiting
        kv owns (j, 2cp-1-j).  ``i``/``j`` may be traced rank indices."""
        return cls(i * c, (2 * cp - 2 - i) * c,
                   j * c, (2 * cp - 2 - j) * c, c, c)

    def shift_q(self, q0: int) -> "BandMask":
        """The band as seen by a q sub-chunk starting at physical ``q0``."""
        return self._replace(q_off_lo=self.q_off_lo + q0,
                             q_off_hi=self.q_off_hi + q0,
                             q_seg=max(self.q_seg - q0, 0))


def _doc_col(q_doc_start):
    """(Lq,) or (B, Lq) per-row doc-start -> column vector that broadcasts
    against a (…, Lq, Lk) logical-position grid."""
    d = jnp.asarray(q_doc_start, jnp.int32)
    return d[..., :, None]


def _per_batch(x):
    """Lift a per-request (B,) offset to broadcast against (Lq, Lk) index
    grids — masks become (B, Lq, Lk).  Scalars pass through untouched."""
    if isinstance(x, jax.Array) and x.ndim >= 1:
        return x.reshape(x.shape[0], 1, 1)
    return x


def _logical_pos(idx, off_lo, off_hi, seg: int):
    off_lo, off_hi = _per_batch(off_lo), _per_batch(off_hi)
    if seg == 0:
        return idx + off_hi
    return idx + jnp.where(idx < seg, off_lo, off_hi)


def _build_mask(lq: int, lk: int, *, causal: bool, window: int | None,
                kv_valid_len: int | None, kv_start=None,
                mask_offset=None, band: BandMask | None = None,
                q_doc_start=None) -> jax.Array | None:
    """Boolean (Lq, Lk) — or (B, Lq, Lk) for per-request offsets —
    visibility mask, or None if everything is visible.

    ``mask_offset`` overrides the bottom-right alignment delta ``lk - lq``;
    it may be a traced scalar (ring attention passes the *logical* chunk
    distance, which is rank-dependent under SPMD) or a per-request ``(B,)``
    array (ragged continuous-batching decode).  ``band`` generalizes it
    to the segmented zigzag layout and takes precedence.  ``kv_valid_len``
    and ``kv_start`` bound the visible key *physical* index range
    ``[kv_start, kv_valid_len)``; both may also be ``(B,)``.

    ``q_doc_start`` — packed-document block-causal masking: a ``(Lq,)``
    or per-sequence ``(B, Lq)`` int32 table giving, for each *physical*
    q row, the logical start position of the document that row's token
    belongs to.  Keys below that start are invisible (``k_log >=
    doc_start``), which together with the causal band restricts each
    query to its own document.  Requires ``causal=True`` (documents are
    contiguous logical intervals, so causal + lower bound == same-doc).
    """
    if band is not None and not causal and window is None:
        raise ValueError("band only shifts the causal/window band anchors; "
                         "passing one with causal=False and window=None "
                         "would be silently ignored")
    if q_doc_start is not None and not causal:
        raise ValueError("q_doc_start (packed block-causal masking) "
                         "requires causal=True")
    if not causal and window is None and kv_valid_len is None \
            and kv_start is None:
        return None
    if band is None:
        band = BandMask.uniform((lk - lq) if mask_offset is None
                                else mask_offset)
    qi = jnp.arange(lq)[:, None]
    kj = jnp.arange(lk)[None, :]
    q_log = _logical_pos(qi, band.q_off_lo, band.q_off_hi, band.q_seg)
    k_log = _logical_pos(kj, band.k_off_lo, band.k_off_hi, band.k_seg)
    mask = jnp.ones((lq, lk), dtype=bool)
    if causal:
        mask = mask & (k_log <= q_log)
    if q_doc_start is not None:
        mask = mask & (k_log >= _doc_col(q_doc_start))
    if window is not None:
        mask = mask & (k_log >= q_log - (window - 1))
    if kv_valid_len is not None:
        mask = mask & (kj < _per_batch(kv_valid_len))
    if kv_start is not None:
        mask = mask & (kj >= _per_batch(kv_start))
    return mask


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = False, window: int | None = None,
                  softcap: float = 0.0, scale: float | None = None,
                  kv_valid_len: int | None = None, kv_start=None,
                  mask_offset=None, band: BandMask | None = None,
                  q_doc_start=None,
                  bias: jax.Array | None = None):
    """Dense fp32 attention oracle.  Returns (out, lse).

    out: (B, Lq, Hq, D) in q.dtype;  lse: (B, Hq, Lq) fp32.
    """
    b, lq, hq, d = q.shape
    _, lk, hkv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    group = hq // hkv
    if group > 1:
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)

    # Score/probability tensors materialize in the *input* dtype: on real
    # TPUs the Pallas kernel keeps them in VMEM (zero HBM traffic), so the
    # bf16 lowering is the closer stand-in for bf16 models; reductions and
    # the returned lse stay fp32.  fp32 inputs keep full-fp32 math (tests).
    sdt = q.dtype if q.dtype != jnp.float64 else jnp.float32
    s = jnp.einsum("bihd,bjhd->bihj", q, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    if bias is not None:
        s = s + jnp.transpose(bias.astype(jnp.float32), (0, 2, 1, 3))
    mask = _build_mask(lq, lk, causal=causal, window=window,
                       kv_valid_len=kv_valid_len, kv_start=kv_start,
                       mask_offset=mask_offset, band=band,
                       q_doc_start=q_doc_start)
    if mask is not None:
        # s is (B, Lq, H, Lk): lift (Lq, Lk) or per-request (B, Lq, Lk).
        mask_s = mask[None, :, None] if mask.ndim == 2 else mask[:, :, None]
        s = jnp.where(mask_s, s, NEG_INF)

    m = jnp.max(s, axis=-1)                      # (B, Lq, H)
    m_safe = jnp.where(m <= NEG_INF / 2, 0.0, m)
    p = jnp.exp(s - m_safe[..., None]).astype(sdt)
    if mask is not None:
        p = jnp.where(mask_s, p, 0)
    l = jnp.sum(p.astype(jnp.float32), axis=-1)  # (B, Lq, H)
    l_safe = jnp.where(l == 0.0, 1.0, l)
    out = jnp.einsum("bihj,bjhd->bihd", p, v,
                     preferred_element_type=jnp.float32)
    out = out / l_safe[..., None]
    lse = jnp.where(l == 0.0, NEG_INF, m_safe + jnp.log(l_safe))
    return (out.astype(q.dtype),
            jnp.transpose(lse, (0, 2, 1)))


def attention_bwd_ref(q, k, v, out, lse, do, *,
                      causal: bool = False, window: int | None = None,
                      softcap: float = 0.0, scale: float | None = None,
                      kv_valid_len: int | None = None, kv_start=None,
                      mask_offset=None, band: BandMask | None = None,
                      q_doc_start=None):
    """Chunk-level attention backward given *global* (out, lse).

    This is the ring-attention backward building block: ``lse``/``out`` are
    the final combined values over the union of all KV chunks, while
    ``k``/``v`` are one visiting chunk; the returned ``dk``/``dv`` are that
    chunk's contributions and ``dq`` is the partial dq to accumulate.

    Shapes: q (B,Lq,Hq,D), k/v (B,Lk,Hkv,D), out/do (B,Lq,Hq,D),
    lse (B,Hq,Lq).  Returns (dq, dk, dv) in input dtypes.
    """
    b, lq, hq, d = q.shape
    _, lk, hkv, _ = k.shape
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    kf = jnp.repeat(k, group, axis=2).astype(jnp.float32) if group > 1 \
        else k.astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=2).astype(jnp.float32) if group > 1 \
        else v.astype(jnp.float32)
    qf = q.astype(jnp.float32)
    dof = do.astype(jnp.float32)
    outf = out.astype(jnp.float32)

    s_raw = jnp.einsum("bihd,bjhd->bhij", qf, kf) * scale
    s = softcap * jnp.tanh(s_raw / softcap) if softcap else s_raw
    mask = _build_mask(lq, lk, causal=causal, window=window,
                       kv_valid_len=kv_valid_len, kv_start=kv_start,
                       mask_offset=mask_offset, band=band,
                       q_doc_start=q_doc_start)
    shift = jnp.where(lse <= NEG_INF / 2, 0.0, lse)      # (B,H,Lq)
    p = jnp.exp(s - shift[..., None])
    if mask is not None:
        # s is (B, H, Lq, Lk) here.
        mask_s = mask[None, None] if mask.ndim == 2 else mask[:, None]
        p = jnp.where(mask_s, p, 0.0)
    dsum = jnp.sum(dof * outf, axis=-1)                  # (B,Lq,H)
    dsum = jnp.transpose(dsum, (0, 2, 1))                # (B,H,Lq)
    dp = jnp.einsum("bihd,bjhd->bhij", dof, vf)
    ds = p * (dp - dsum[..., None])
    if softcap:
        ds = ds * (1.0 - (s / softcap) ** 2)
    ds = ds * scale
    dq = jnp.einsum("bhij,bjhd->bihd", ds, kf)
    dk_exp = jnp.einsum("bhij,bihd->bjhd", ds, qf)
    dv_exp = jnp.einsum("bhij,bihd->bjhd", p, dof)
    if group > 1:
        dk = dk_exp.reshape(b, lk, hkv, group, d).sum(axis=3)
        dv = dv_exp.reshape(b, lk, hkv, group, d).sum(axis=3)
    else:
        dk, dv = dk_exp, dv_exp
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def combine_attention(parts):
    """LSE-weighted combine of partial attention results.

    ``parts``: iterable of ``(out, lse)`` with out (B, L, H, D),
    lse (B, H, L).  Each part must be the softmax-normalized attention over a
    disjoint subset of keys together with its logsumexp.  Returns the exact
    attention over the union — the update rule of ring attention /
    flash-decoding.
    """
    parts = list(parts)
    outs = jnp.stack([p[0].astype(jnp.float32) for p in parts])   # (N,B,L,H,D)
    lses = jnp.stack([p[1] for p in parts])                       # (N,B,H,L)
    m = jnp.max(lses, axis=0)                                     # (B,H,L)
    m_safe = jnp.where(m <= NEG_INF / 2, 0.0, m)
    w = jnp.exp(lses - m_safe[None])                              # (N,B,H,L)
    w = jnp.where(lses <= NEG_INF / 2, 0.0, w)
    denom = jnp.sum(w, axis=0)                                    # (B,H,L)
    denom_safe = jnp.where(denom == 0.0, 1.0, denom)
    # (N,B,H,L) -> weights aligned to out layout (N,B,L,H,1)
    w_o = jnp.transpose(w, (0, 1, 3, 2))[..., None]
    out = jnp.sum(outs * w_o, axis=0) / jnp.transpose(
        denom_safe, (0, 2, 1))[..., None]
    lse = jnp.where(denom == 0.0, NEG_INF, m_safe + jnp.log(denom_safe))
    return out.astype(parts[0][0].dtype), lse


def combine_pair(out_a, lse_a, out_b, lse_b):
    """Two-way combine (the in-loop ring update)."""
    return combine_attention([(out_a, lse_a), (out_b, lse_b)])


def _chunked(fn, lq: int, q_chunk: int):
    """Static q-chunk bounds (python-unrolled => exact cost accounting)."""
    q_chunk = max(1, min(q_chunk, lq))
    bounds = list(range(0, lq, q_chunk))
    return bounds, q_chunk


def _chunk_band(band, mask_offset, lq: int, lk: int, q0: int, *,
                causal, window) -> BandMask | None:
    """The band for the q sub-chunk starting at physical ``q0`` (None when
    no band geometry applies — nothing to re-anchor per chunk)."""
    if not causal and window is None:
        return None
    if band is None:
        band = BandMask.uniform((lk - lq) if mask_offset is None
                                else mask_offset)
    return band.shift_q(q0)


def _chunk_doc(q_doc_start, q0: int, q_chunk: int):
    """Slice the per-row doc-start table to a q sub-chunk (physical rows
    index it, so chunking is a plain slice)."""
    if q_doc_start is None:
        return None
    return jnp.asarray(q_doc_start)[..., q0:q0 + q_chunk]


def attention_ref_chunked(q, k, v, *, causal=False, window=None,
                          softcap=0.0, scale=None, kv_valid_len=None,
                          kv_start=None,
                          mask_offset=None, band: BandMask | None = None,
                          q_doc_start=None,
                          q_chunk: int = 1024):
    """Flash-semantics lowering of the oracle: scores materialize only per
    q-chunk (O(q_chunk × Lk)), matching what the Pallas kernel does in
    VMEM.  Python-unrolled so compiled FLOPs/bytes are exact.

    Numerically identical to attention_ref (same fp32 softmax math).
    """
    b, lq, hq, d = q.shape
    bounds, q_chunk = _chunked(None, lq, q_chunk)
    if len(bounds) == 1:
        return attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap, scale=scale,
                             kv_valid_len=kv_valid_len, kv_start=kv_start,
                             mask_offset=mask_offset, band=band,
                             q_doc_start=q_doc_start)
    lk = k.shape[1]
    outs, lses = [], []
    for q0 in bounds:
        qc = q[:, q0:q0 + q_chunk]
        o, l = attention_ref(qc, k, v, causal=causal, window=window,
                             softcap=softcap, scale=scale,
                             kv_valid_len=kv_valid_len, kv_start=kv_start,
                             band=_chunk_band(band, mask_offset, lq, lk,
                                              q0, causal=causal,
                                              window=window),
                             q_doc_start=_chunk_doc(q_doc_start, q0,
                                                    q_chunk))
        outs.append(o)
        lses.append(l)
    return (jnp.concatenate(outs, axis=1),
            jnp.concatenate(lses, axis=2))


def attention_bwd_ref_chunked(q, k, v, out, lse, do, *, causal=False,
                              window=None, softcap=0.0, scale=None,
                              kv_valid_len=None, mask_offset=None,
                              band: BandMask | None = None,
                              q_doc_start=None,
                              q_chunk: int = 1024):
    """q-chunked chunk-backward; dk/dv accumulate in fp32."""
    b, lq, hq, d = q.shape
    bounds, q_chunk = _chunked(None, lq, q_chunk)
    if len(bounds) == 1:
        return attention_bwd_ref(q, k, v, out, lse, do, causal=causal,
                                 window=window, softcap=softcap,
                                 scale=scale, kv_valid_len=kv_valid_len,
                                 mask_offset=mask_offset, band=band,
                                 q_doc_start=q_doc_start)
    lk = k.shape[1]
    dqs = []
    dk = jnp.zeros(k.shape, jnp.float32)
    dv = jnp.zeros(v.shape, jnp.float32)
    for q0 in bounds:
        sl = slice(q0, q0 + q_chunk)
        dq_c, dk_c, dv_c = attention_bwd_ref(
            q[:, sl], k, v, out[:, sl], lse[:, :, sl], do[:, sl],
            causal=causal, window=window, softcap=softcap, scale=scale,
            kv_valid_len=kv_valid_len,
            band=_chunk_band(band, mask_offset, lq, lk, q0,
                             causal=causal, window=window),
            q_doc_start=_chunk_doc(q_doc_start, q0, q_chunk))
        dqs.append(dq_c)
        dk = dk + dk_c.astype(jnp.float32)
        dv = dv + dv_c.astype(jnp.float32)
    return (jnp.concatenate(dqs, axis=1), dk.astype(k.dtype),
            dv.astype(v.dtype))
