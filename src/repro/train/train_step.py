"""Jitted train/eval step builders, driven entirely by an ExecutionPlan.

The plan owns the mesh, the hybrid-ZeRO shardings, the remat policy and
the microbatch grid; this module turns it into a jitted step function.

**Microbatched gradient accumulation** (``plan.grad_accum > 1``): the
batch arrives as ``(accum, microbatch, ...)`` and a ``jax.lax.scan``
runs one forward+backward per microbatch.  The gradient carry stays in
the *compute* dtype (bf16 for mixed-precision configs — half the HBM and
wire bytes of an fp32 carry); the in-loop work is pure accumulation.
The fp32 upcast and the AdamW update — where the accumulated grads are
reduced into the ZeRO-sharded optimizer shard (GSPMD's reduce-scatter)
— sit *outside* the loop: one reduction point per step, not one per
microbatch.  That structure (pinned by ``tests/test_plan.py``'s jaxpr
check) is exactly what XLA's while-loop all-reduce code motion needs to
emit a single post-loop reduce-scatter on TPU.  Remat
applies inside each microbatch's forward (``plan.cfg.remat`` —
Selective Checkpoint++ per microbatch), and each microbatch's
activations die with its scan iteration.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.plan import ExecutionPlan
from repro.models.model import cast_params_once, forward_loss
from repro.train.optimizer import adamw_update


def make_train_step(plan: ExecutionPlan):
    """Mixed-precision step: the model is differentiated w.r.t. the *bf16*
    param tree, so the cross-device gradient reduction runs in bf16 (half
    the wire bytes of an fp32 all-reduce); the fp32→bf16 master cast and
    the bf16→fp32 grad upcast are local.  AdamW updates the fp32 masters.
    fp32-configured models (tests) are bit-identical to the plain path.
    """
    cfg, rt, opt_cfg, accum = plan.cfg, plan.rt, plan.opt, plan.grad_accum

    def step_fn(params, opt_state, batch):
        p_half = cast_params_once(params, cfg)
        grad_of = jax.value_and_grad(
            lambda ph, mb: forward_loss(ph, mb, rt, cfg),
            has_aux=True)
        if accum == 1:
            (_, metrics), grads_half = grad_of(p_half, batch)
        else:
            def micro(g_acc, mb):
                (_, m), g = grad_of(p_half, mb)
                # token-weighted accumulation: each microbatch's grad is
                # of its *mean* loss, so scale by its valid-token count
                # before summing.  With equal counts (SyntheticLM) this
                # reduces to the plain mean over microbatches; with
                # unequal counts (PackedLM bins carry different tail
                # padding) it reproduces the flat large-batch step
                # instead of skewing toward sparsely-filled bins.
                n = m["n_tokens"].astype(cfg.compute_dtype)
                g_acc = jax.tree.map(lambda a, g: a + g * n, g_acc, g)
                return g_acc, m

            grads_half, ms = lax.scan(
                micro, jax.tree.map(jnp.zeros_like, p_half), batch)
            n_total = ms["n_tokens"].sum(0)
            grads_half = jax.tree.map(
                lambda g: g / n_total.astype(g.dtype), grads_half)
            w = ms["n_tokens"] / n_total                # (accum,)
            metrics = {k: (v.sum(0) if k == "n_tokens"
                           else (v * w).sum(0))
                       for k, v in ms.items()}
        grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads_half,
                             params)
        new_params, new_state, om = adamw_update(params, grads, opt_state,
                                                 opt_cfg)
        metrics = dict(metrics)
        metrics.update(om)
        return new_params, new_state, metrics
    return step_fn


def jit_train_step(plan: ExecutionPlan, params, *, donate: bool = True):
    """Returns (jitted_step, param_shardings, opt_state_shardings)."""
    p_sh = plan.param_shardings(params)
    o_sh = plan.opt_shardings(p_sh)
    b_sh = plan.batch_shardings("train")
    fn = jax.jit(
        make_train_step(plan),
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1) if donate else ())
    return fn, p_sh, o_sh
