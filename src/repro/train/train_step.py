"""Jitted train/eval step builders with explicit in/out shardings."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.runtime import Runtime
from repro.core.topology import BATCH_AXES, SEQ_AXES
from repro.core.zero import zero_shardings
from repro.models.model import ModelConfig, cast_params_once, forward_loss
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state


def batch_shardings(mesh, cfg: ModelConfig):
    tok = NamedSharding(mesh, P(BATCH_AXES, SEQ_AXES))
    out = {"tokens": tok, "labels": tok, "positions": tok}
    if cfg.family == "encdec":
        out["frames"] = NamedSharding(mesh, P(BATCH_AXES, SEQ_AXES, None))
    return out


def opt_shardings(param_sh, mesh):
    return {"m": param_sh, "v": param_sh,
            "step": NamedSharding(mesh, P())}


def make_train_step(cfg: ModelConfig, rt: Runtime, opt_cfg: OptConfig):
    """Mixed-precision step: the model is differentiated w.r.t. the *bf16*
    param tree, so the cross-device gradient reduction runs in bf16 (half
    the wire bytes of an fp32 all-reduce); the fp32→bf16 master cast and
    the bf16→fp32 grad upcast are local.  AdamW updates the fp32 masters.
    fp32-configured models (tests) are bit-identical to the plain path.
    """
    def step_fn(params, opt_state, batch):
        p_half = cast_params_once(params, cfg)
        (loss, metrics), grads_half = jax.value_and_grad(
            lambda ph: forward_loss(ph, batch, rt, cfg),
            has_aux=True)(p_half)
        grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads_half,
                             params)
        new_params, new_state, om = adamw_update(params, grads, opt_state,
                                                 opt_cfg)
        metrics = dict(metrics)
        metrics.update(om)
        return new_params, new_state, metrics
    return step_fn


def jit_train_step(cfg: ModelConfig, rt: Runtime, opt_cfg: OptConfig,
                   params, *, donate: bool = True):
    """Returns (jitted_step, param_shardings, opt_state_shardings)."""
    mesh = rt.mesh
    p_sh = zero_shardings(params, mesh)
    o_sh = opt_shardings(p_sh, mesh)
    b_sh = batch_shardings(mesh, cfg)
    fn = jax.jit(
        make_train_step(cfg, rt, opt_cfg),
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1) if donate else ())
    return fn, p_sh, o_sh
