"""End-to-end trainer: data -> jitted step -> metrics, with checkpointing,
preemption flush, deterministic resume, and straggler monitoring.

The trainer consumes an ``ExecutionPlan`` — it makes no mesh/sharding/
remat decisions of its own.  The hot loop is *sync-free*: metrics stay on
device and are only materialized (forcing a host sync) at ``log_every``
boundaries, so step dispatch pipelines ahead of execution instead of
blocking on ``float(loss)`` every iteration.
"""
from __future__ import annotations

import dataclasses
import logging

import jax

from repro.core.plan import ExecutionPlan
from repro.data.pipeline import DataConfig, PackedLM, SyntheticLM
from repro.models.model import init_params
from repro.runtime import checkpoint as ckpt
from repro.runtime.resilience import PreemptionGuard, StepMonitor
from repro.train.optimizer import init_opt_state
from repro.train.train_step import jit_train_step

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainerConfig:
    num_steps: int = 100
    ckpt_dir: str | None = None
    ckpt_every: int = 50      # async-save cadence (steps)
    log_every: int = 10
    seed: int = 0
    resume: bool = True       # auto-restore the latest step in ckpt_dir


class Trainer:
    def __init__(self, plan: ExecutionPlan, data_cfg: DataConfig,
                 tcfg: TrainerConfig):
        self.plan, self.tcfg = plan, tcfg
        self.cfg, self.rt = plan.cfg, plan.rt
        if data_cfg.grad_accum != plan.grad_accum:
            data_cfg = dataclasses.replace(data_cfg,
                                           grad_accum=plan.grad_accum)
        # packed plans pull document batches (with doc_start boundary
        # tables) — the step function's batch pytree must match
        # plan.batch_shardings("train"), which adds doc_start iff packed
        self.data = (PackedLM if plan.packed else SyntheticLM)(
            data_cfg, plan.cfg)
        self.monitor = StepMonitor()
        self.guard = PreemptionGuard()
        self.guard.install()

        with plan.mesh:
            params = init_params(plan.cfg, jax.random.PRNGKey(tcfg.seed))
            self.step_fn, self.p_sh, self.o_sh = jit_train_step(plan,
                                                                params)
            self.params = jax.device_put(params, self.p_sh)
            self.opt_state = jax.device_put(init_opt_state(params),
                                            self.o_sh)
        self.start_step = 0
        self.ckpter = None
        if tcfg.ckpt_dir:
            self.ckpter = ckpt.CheckpointManager(tcfg.ckpt_dir, plan=plan)
            if tcfg.resume and self.ckpter.latest_step() is not None:
                self.restore()

    def restore(self, step: int | None = None):
        """Restore (latest step by default) through *this* run's plan:
        the manager reassembles the saved shards and reshards them onto
        the current layout — a checkpoint saved under a different
        dp/ZeRO extent resumes here without migration."""
        state = {"params": self.params, "opt": self.opt_state}
        state, step = self.ckpter.restore(state, step=step)
        self.params, self.opt_state = state["params"], state["opt"]
        self.start_step = step
        log.info("restored checkpoint at step %d", step)

    def save(self, step: int):
        if self.ckpter is None:
            return
        self.ckpter.save_async({"params": self.params,
                                "opt": self.opt_state}, step)

    def run(self):
        losses = []                    # device scalars until the end
        pending = 0                    # steps dispatched since last sync
        remaining = self.tcfg.num_steps - self.start_step
        with self.plan.mesh:
            self.monitor.start()
            # deterministic resume: the source indexes by step, so a
            # restored run *skips* to start_step instead of replaying
            for step, batch in self.data.iter_batches(self.start_step,
                                                      remaining):
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, batch)
                pending += 1
                if step % self.tcfg.log_every == 0:
                    # the only in-loop host sync; step time is amortized
                    # over the steps dispatched since the previous sync
                    loss = float(metrics["loss"])
                    n_flagged = len(self.monitor.flagged)
                    self.monitor.lap(pending)
                    pending = 0
                    log.info("step %d loss %.4f gnorm %.3f (%.2fs/step)",
                             step, loss, float(metrics["grad_norm"]),
                             self.monitor.median)
                    for s, dt, med in self.monitor.flagged[n_flagged:]:
                        log.warning("straggler flagged at step %d: "
                                    "%.3fs vs median %.3fs", s, dt, med)
                losses.append(metrics["loss"])
                if self.ckpter and (step + 1) % self.tcfg.ckpt_every == 0:
                    self.save(step + 1)
                if self.guard.requested:
                    # SIGTERM landed: flush a final checkpoint at this
                    # step boundary and stop cleanly
                    log.warning("preemption requested: flushing "
                                "checkpoint at step %d", step + 1)
                    self.save(step + 1)
                    if self.ckpter:
                        self.ckpter.flush()
                    break
            losses = [float(x) for x in jax.device_get(losses)]
            if pending:                # attribute the synced tail
                self.monitor.lap(pending)
        if self.ckpter:
            self.ckpter.flush()
        return losses
