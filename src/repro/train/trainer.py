"""End-to-end trainer: data -> jitted step -> metrics, with checkpointing,
preemption flush, deterministic resume, and straggler monitoring."""
from __future__ import annotations

import dataclasses
import logging

import jax
import jax.numpy as jnp

from repro.core.runtime import Runtime
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.model import ModelConfig, init_params
from repro.runtime import checkpoint as ckpt
from repro.runtime.resilience import PreemptionGuard, StepMonitor
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import jit_train_step

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainerConfig:
    num_steps: int = 100
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    log_every: int = 10
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, rt: Runtime, opt_cfg: OptConfig,
                 data_cfg: DataConfig, tcfg: TrainerConfig):
        self.cfg, self.rt, self.tcfg = cfg, rt, tcfg
        self.data = SyntheticLM(data_cfg, cfg)
        self.monitor = StepMonitor()
        self.guard = PreemptionGuard()
        self.guard.install()

        with rt.mesh:
            params = init_params(cfg, jax.random.PRNGKey(tcfg.seed))
            self.step_fn, self.p_sh, self.o_sh = jit_train_step(
                cfg, rt, opt_cfg, params)
            self.params = jax.device_put(params, self.p_sh)
            self.opt_state = jax.device_put(init_opt_state(params),
                                            self.o_sh)
        self.start_step = 0
        self.ckpter = None
        if tcfg.ckpt_dir:
            self.ckpter = ckpt.AsyncCheckpointer(tcfg.ckpt_dir)
            last = ckpt.latest_step(tcfg.ckpt_dir)
            if last is not None:
                self.restore(last)

    def restore(self, step: int):
        state = {"params": self.params, "opt": self.opt_state}
        shardings = {"params": self.p_sh, "opt": self.o_sh}
        (state, _) = ckpt.restore(state, self.tcfg.ckpt_dir, step=step,
                                  shardings=shardings)
        self.params, self.opt_state = state["params"], state["opt"]
        self.start_step = step
        log.info("restored checkpoint at step %d", step)

    def save(self, step: int):
        if self.ckpter is None:
            return
        self.ckpter.save_async({"params": self.params,
                                "opt": self.opt_state}, step)

    def run(self):
        losses = []
        with self.rt.mesh:
            for step in range(self.start_step, self.tcfg.num_steps):
                batch = self.data.batch(step)
                self.monitor.start()
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, batch)
                loss = float(metrics["loss"])
                self.monitor.stop()
                losses.append(loss)
                if step % self.tcfg.log_every == 0:
                    log.info("step %d loss %.4f gnorm %.3f (%.2fs/step)",
                             step, loss, float(metrics["grad_norm"]),
                             self.monitor.median)
                if self.ckpter and (step + 1) % self.tcfg.ckpt_every == 0:
                    self.save(step + 1)
                if self.guard.requested:
                    log.warning("preemption requested: flushing checkpoint")
                    self.save(step + 1)
                    break
        if self.ckpter:
            self.ckpter.wait()
        return losses
