"""AdamW with warmup+cosine schedule, global-norm clipping, ZeRO-sharded
moments, and optional int8 error-feedback gradient compression.

This module is pure math over pytrees; *where* the state lives is the
ExecutionPlan's decision: ``plan.opt_shardings`` makes the moments
inherit the params' hybrid-ZeRO shardings (at the AMSP extent the plan
chose), so the update is fully sharded — XLA reduce-scatters grads into
the shard and all-gathers updated params at next use.  Under gradient
accumulation the update runs once per step, on the microbatch-mean
grads (train/train_step.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_opt_state(params):
    zeros = lambda p: jnp.zeros_like(p)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-12))
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / c1
        vh = v / c2
        step_dir = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            step_dir = step_dir + cfg.weight_decay * p
        return (p - lr * step_dir).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# int8 error-feedback gradient compression (optional, for DP all-reduce)
# ---------------------------------------------------------------------------

def quantize_int8(x, err):
    """Symmetric per-tensor int8 quantization with error feedback.

    Returns (q int8, scale, new_err).  ``dequantize(q, scale)`` reconstructs;
    the residual is carried into the next step (error feedback keeps the
    long-run bias at zero — property-tested in tests/test_substrates.py).
    """
    xf = x.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    new_err = xf - q.astype(jnp.float32) * scale
    return q, scale, new_err


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(g, err, axis_name):
    """psum an int8-quantized gradient over ``axis_name`` (inside
    shard_map), with local error feedback.  Returns (g_sum, new_err)."""
    q, scale, new_err = quantize_int8(g, err)
    deq = dequantize_int8(q, scale)                 # simulate int8 wire
    summed = jax.lax.psum(deq, axis_name)
    return summed, new_err
