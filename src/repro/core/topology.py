"""Mesh topology: the 5-axis LoongTrain mesh and device placement.

Axes (canonical order): ``("pod", "data", "head", "outer", "inner")``

* ``pod``    — cross-pod data parallelism (DCN-connected pods).
* ``data``   — in-pod data parallelism.
* ``head``   — head parallelism (d_hp); the Ulysses ``SeqAlltoAll`` group.
* ``outer``  — outer ring of Double-Ring-Attention (d_cp / w groups).
* ``inner``  — inner ring (w); ``d_cp = outer * inner``, ``d_sp = hp * cp``.

Paper §4.4 placement strategies map to *which axis is minor (contiguous)*
in the device array: on a TPU slice, contiguity in the mesh device order is
ICI locality, the analogue of "colocated on a node".

* head-first:    model axis reshaped ``(outer, inner, head)`` — head minor,
                 so the SeqAlltoAll group is the most-local set of chips.
* context-first: model axis reshaped ``(head, outer, inner)`` — inner minor,
                 so the inner ring is the most-local set of chips.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

AXIS_POD = "pod"
AXIS_DATA = "data"
AXIS_HP = "head"
AXIS_OUTER = "outer"
AXIS_INNER = "inner"
MESH_AXES = (AXIS_POD, AXIS_DATA, AXIS_HP, AXIS_OUTER, AXIS_INNER)

#: Data-parallel axes (global batch is sharded over these).
BATCH_AXES = (AXIS_POD, AXIS_DATA)
#: Sequence-parallel axes, major-to-minor for the S dimension.  The order
#: makes the head axis minor so that SeqAlltoAll's concat over head peers
#: yields a contiguous S/d_cp block per context rank (see attention2d.py).
SEQ_AXES = (AXIS_OUTER, AXIS_INNER, AXIS_HP)
#: All non-batch axes — used for hybrid-ZeRO sharding of params/opt state.
MODEL_AXES = (AXIS_HP, AXIS_OUTER, AXIS_INNER)
ZERO_AXES = (AXIS_DATA,) + MODEL_AXES


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """LoongTrain parallel layout.  d_sp = hp * cp_outer * cp_inner."""
    dp: int = 1
    hp: int = 1
    cp_outer: int = 1
    cp_inner: int = 1
    pods: int = 1
    placement: str = "head_first"      # or "context_first"

    @property
    def cp(self) -> int:
        return self.cp_outer * self.cp_inner

    @property
    def sp(self) -> int:
        return self.hp * self.cp

    @property
    def model_size(self) -> int:
        return self.sp

    @property
    def num_devices(self) -> int:
        return self.pods * self.dp * self.sp

    def validate(self):
        assert self.placement in ("head_first", "context_first"), self.placement
        for v in (self.dp, self.hp, self.cp_outer, self.cp_inner, self.pods):
            assert v >= 1


def _reshape_model_axis(dev: np.ndarray, pc: ParallelConfig) -> np.ndarray:
    """dev: (pods, dp, model) -> (pods, dp, hp, outer, inner)."""
    pods, dp, model = dev.shape
    assert model == pc.sp, (model, pc.sp)
    if pc.placement == "head_first":
        # head minor: SeqAlltoAll group gets ICI-adjacent chips.
        d = dev.reshape(pods, dp, pc.cp_outer, pc.cp_inner, pc.hp)
        return d.transpose(0, 1, 4, 2, 3)
    # context-first: inner ring minor.
    return dev.reshape(pods, dp, pc.hp, pc.cp_outer, pc.cp_inner)


def refine_mesh(base: Mesh, pc: ParallelConfig) -> Mesh:
    """Split a ``(data, model)`` / ``(pod, data, model)`` production mesh
    into the 5-axis LoongTrain mesh without changing device order."""
    pc.validate()
    dev = base.devices
    if dev.ndim == 2:
        dev = dev[np.newaxis]
    assert dev.ndim == 3, dev.shape
    assert dev.shape[0] == pc.pods, (dev.shape, pc)
    assert dev.shape[1] == pc.dp, (dev.shape, pc)
    return Mesh(_reshape_model_axis(dev, pc), MESH_AXES)


def make_mesh(pc: ParallelConfig, devices=None) -> Mesh:
    """Build the 5-axis mesh directly from a flat device list (tests,
    single-host runs)."""
    pc.validate()
    devices = list(jax.devices()) if devices is None else list(devices)
    n = pc.num_devices
    assert len(devices) >= n, (len(devices), n)
    dev = np.array(devices[:n]).reshape(pc.pods, pc.dp, pc.sp)
    return Mesh(_reshape_model_axis(dev, pc), MESH_AXES)


def batch_spec(*trailing) -> P:
    return P(BATCH_AXES, *trailing)


def seq_sharded_spec(batch_first: bool = True, *trailing) -> P:
    """Spec for an activation (B, S, ...) with S sharded over all sp axes."""
    if batch_first:
        return P(BATCH_AXES, SEQ_AXES, *trailing)
    return P(SEQ_AXES, *trailing)


def factor_cp(cp: int, inner: int | None = None) -> tuple[int, int]:
    """Choose (outer, inner) for a given cp; default inner = min(cp, 4),
    mirroring the paper's 'w = number of NICs' heuristic (ICI dim extent)."""
    if inner is None:
        inner = math.gcd(cp, 4)
    assert cp % inner == 0, (cp, inner)
    return cp // inner, inner
