"""ExecutionPlan: the single planning layer for every cross-layer decision.

LoongTrain's system contribution is the *composition* of head×context
placement (§4.4), hybrid ZeRO (§5.1), and Selective Checkpoint++ (§5.2)
tuned together per workload.  ``build_plan`` makes all of those choices
once, from ``(ParallelConfig, ModelConfig, OptConfig, memory budget)``,
and every entry point — launchers, trainer, dry-run, examples — consumes
the resulting ``ExecutionPlan`` instead of re-deriving mesh/sharding
facts:

* **mesh** — the 5-axis LoongTrain mesh (built from a flat device list or
  refined from a production ``(pod, data, model)`` grid) with the
  head-first / context-first placement strategy.
* **hybrid ZeRO** — the sharding extent (Full-Replica / dp / sp / dp×sp,
  AMSP's three modes) is chosen from a parameter+optimizer memory model:
  the *least* sharded extent whose state fits the per-device budget wins,
  minimizing collective latency (the seed hardcoded most-sharded-first).
* **remat** — ``none | full | scpp`` from an activation estimate when
  asked for ``"auto"``; the decision lands in ``cfg.remat`` so the model
  stack reads one source of truth.
* **gradient accumulation** — ``grad_accum`` microbatches per step; the
  plan owns the ``(accum, microbatch, ...)`` batch layout and shardings.
* **Attn2DConfig / batch / param / opt shardings** — derived here only.

``plan.describe()`` prints the whole story as one table, so train, serve
and dry-run all report identically.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import TYPE_CHECKING

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.attention2d import Attn2DConfig, attn2d_config
from repro.core.runtime import Runtime
from repro.core.topology import (AXIS_DATA, AXIS_POD, BATCH_AXES, MESH_AXES,
                                 MODEL_AXES, SEQ_AXES, ParallelConfig,
                                 make_mesh, refine_mesh)
from repro.core.zero import (_group_size, leaf_extent, tp_shardings,
                             zero_shardings)

if TYPE_CHECKING:                                  # avoid core -> models
    from repro.models.model import ModelConfig     # import at runtime
    from repro.train.optimizer import OptConfig

#: per-parameter state bytes: fp32 master + Adam m + Adam v
STATE_BYTES_PER_PARAM = 12
#: transient bf16 compute copy of the (matrix) params
HALF_BYTES_PER_PARAM = 2
#: assumed device→host snapshot bandwidth for the checkpoint-stall
#: estimate (PCIe-gen4-ish); the disk side is hidden by the async writer
CKPT_D2H_BYTES_PER_S = 16e9
#: rough live activation width per token per layer, in units of
#: d_model × 2 bytes: hidden + norms + q/k/v/o + gate/up intermediates
#: when nothing is rematerialized; the saved-residual footprint per layer
#: under full / SC++ checkpointing.
ACT_UNITS = {"none": 14, "scpp": 2, "full": 1}
#: fraction of the device budget the optimizer/param state may occupy —
#: the rest is headroom for activations, grads and XLA workspace.
STATE_BUDGET_FRAC = 0.6

#: serve mode: fraction of the device budget available to bf16 weights +
#: the paged-KV block pool (the rest is activation/workspace headroom).
SERVE_BUDGET_FRAC = 0.8

#: assumed host↔device wire bandwidth for the chunk-offload traffic model
#: (PCIe-gen4-ish, matching the checkpoint snapshot path)
OFFLOAD_WIRE_BYTES_PER_S = 16e9


def offload_resident_frac(chunks: int) -> float:
    """HBM-resident fraction of a chunk-pipelined tensor: the active
    chunk plus the prefetched next one (the double-buffer schedule the
    ``OffloadManager`` runs).  1.0 when not chunked."""
    if chunks <= 1:
        return 1.0
    return min(1.0, 2.0 / chunks)


def offload_split(total_bytes: float, chunks: int) -> tuple[float, float]:
    """``(device_bytes, host_bytes)`` of a chunk-pipelined tensor.

    The single split rule shared by the train activation model and the
    serve KV-pool model, so a byte lives on exactly one side of the
    accounting — never device-counted *and* host-counted."""
    dev = total_bytes * offload_resident_frac(chunks)
    return dev, total_bytes - dev

#: AMSP sharding modes, smallest extent first (Full-Replica → dp-only →
#: sp-only → full dp×sp).  ``build_plan`` picks the first that fits.
ZERO_MODES = (
    ("replica", ()),
    ("dp", (AXIS_DATA,)),
    ("sp", MODEL_AXES),
    ("dp_sp", (AXIS_DATA,) + MODEL_AXES),
    ("pod_dp_sp", (AXIS_POD, AXIS_DATA) + MODEL_AXES),
)


@functools.lru_cache(maxsize=64)
def _params_struct(cfg):
    """Abstract param tree for a (hashable) ModelConfig — cached so the
    memory model and describe()/leaf_extents() trace the model once."""
    from repro.models.model import init_params
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def _param_count(cfg) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(_params_struct(cfg)))


def _fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB"):
        if abs(b) < 1024 or unit == "GB":
            return f"{b:.1f}{unit}" if unit != "B" else f"{int(b)}B"
        b /= 1024
    return f"{b:.1f}GB"


class _ShapeOnlyMesh:
    """Duck-typed stand-in for a ``Mesh`` where only ``.shape`` is read
    (``choose_zero_mode`` / ``_group_size``).  Lets the memory model run
    from a ``ParallelConfig`` alone — the PlanTuner prunes thousands of
    candidate points without constructing a device mesh per point."""

    def __init__(self, pc: ParallelConfig):
        self.shape = {AXIS_POD: pc.pods, AXIS_DATA: pc.dp,
                      MODEL_AXES[0]: pc.hp, MODEL_AXES[1]: pc.cp_outer,
                      MODEL_AXES[2]: pc.cp_inner}


def choose_zero_mode(n_params: int, mesh, budget_bytes: float,
                     *, include_pod: bool = False):
    """AMSP mode selection from the param+optimizer memory model.

    Returns ``(mode_name, group, groups)`` where ``groups`` is the
    preference order handed to ``leaf_spec``: the chosen group first,
    then every smaller extent as a fallback for leaves the chosen group
    cannot divide (after ``leaf_spec``'s own sub-group dropping).
    """
    state = n_params * (STATE_BYTES_PER_PARAM + HALF_BYTES_PER_PARAM)
    modes = [(name, grp) for name, grp in ZERO_MODES
             if include_pod or AXIS_POD not in grp]
    sized = sorted(((name, grp, _group_size(mesh, grp)) for name, grp
                    in modes), key=lambda t: t[2])
    chosen = sized[-1]                 # largest extent if nothing fits
    for name, grp, g in sized:
        if state / max(g, 1) <= budget_bytes * STATE_BUDGET_FRAC:
            chosen = (name, grp, g)
            break
    fallbacks = tuple(grp for _, grp, g in reversed(sized)
                      if g < chosen[2] and grp)
    groups = ((chosen[1],) if chosen[1] else ()) + fallbacks
    return chosen[0], chosen[1], groups


def choose_remat(cfg, budget_bytes: float, state_dev: float,
                 tokens_dev: float) -> str:
    """Pick ``none | full | scpp`` from the activation estimate: the
    cheapest-recompute policy whose saved activations fit the headroom."""
    headroom = budget_bytes - state_dev
    for policy in ("none", "scpp", "full"):
        saved = (tokens_dev * cfg.d_model * 2
                 * ACT_UNITS[policy] * cfg.num_layers)
        if policy != "none":           # + one layer recompute peak
            saved += tokens_dev * cfg.d_model * 2 * ACT_UNITS["none"]
        if saved <= headroom:
            return policy
    return "full"


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """Geometry of the paged-KV serve engine, chosen by the memory model.

    Field names match ``repro.serve.engine.EngineConfig`` so a spec can be
    handed straight to ``ServeEngine``.
    """
    page_size: int
    num_blocks: int              # physical blocks in the shared pool
    max_blocks_per_seq: int      # block-table width (longest request)
    max_batch: int               # engine decode slots
    prefill_chunk: int
    paged_bytes_per_token: int   # KV bytes/token across paged layers
    window_bytes: int            # fixed ring-buffer bytes per slot


def serve_kv_bytes(cfg) -> tuple[int | None, int]:
    """(paged bytes/token, fixed window-ring bytes per slot) for a config;
    (None, 0) when the family has no paged decode path (ssm state is
    O(1), encdec caches are bounded by max_positions)."""
    if cfg.family not in ("dense", "moe"):
        return None, 0
    itemsize = cfg.compute_dtype.itemsize
    if cfg.mla is not None:
        m = cfg.mla
        return (m.kv_lora + m.d_rope) * itemsize * cfg.num_layers, 0
    groups = cfg.num_layers // cfg.period
    per_tok, win = 0, 0
    for slot in range(cfg.period):
        kind = cfg.attn_kind(slot)
        kv = 2 * cfg.n_kv_heads * cfg.hd * itemsize * groups
        if kind.window is None:
            per_tok += kv
        else:
            win += kv * kind.window
    return per_tok, win


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Every cross-layer execution decision, made once.

    Consumers read decisions from here: ``plan.cfg`` (remat already
    resolved), ``plan.rt`` (mesh + impl + batch axes), the sharding
    factories, and ``plan.grad_accum``.
    """
    cfg: "ModelConfig"               # remat already resolved
    pc: ParallelConfig
    opt: "OptConfig"
    mesh: Mesh
    rt: Runtime
    grad_accum: int = 1
    zero_mode: str = "replica"
    zero_groups: tuple = ()
    memory_budget: float = 16e9      # bytes / device
    #: workload shape the memory model used (None when not supplied)
    seq_len: int | None = None
    global_batch: int | None = None
    #: packed-document training: batches carry a doc_start boundary table
    #: and attention is block-causal per document
    packed: bool = False
    #: expected mean document length of the packed stream (the cost
    #: model's ``packing`` term; None => seq_len, i.e. no packing win)
    mean_doc_len: int | None = None
    #: FPDT chunk pipeline: sequence chunks streamed through attention
    #: with inactive chunks in host memory (1 = fully resident)
    offload_chunks: int = 1
    mem: dict = dataclasses.field(default_factory=dict)

    # -- sharding factories -------------------------------------------------

    def param_shardings(self, params):
        """Hybrid-ZeRO NamedShardings at the chosen extent."""
        return zero_shardings(params, self.mesh, groups=self.zero_groups)

    def opt_shardings(self, param_sh):
        """Optimizer state inherits the param shardings (ZeRO-1/2)."""
        return {"m": param_sh, "v": param_sh,
                "step": NamedSharding(self.mesh, P())}

    def state_shardings(self, state):
        """NamedShardings for a trainer state dict: ``params``/``opt``
        get the hybrid-ZeRO layout, anything else replicates.  This is
        both the layout checkpoints are sharded by on save and the
        target spec ``CheckpointManager.restore`` reshards through."""
        out = {}
        for key, sub in state.items():
            if key == "params":
                out[key] = self.param_shardings(sub)
            elif key == "opt":
                out[key] = self.opt_shardings(self.param_shardings(sub["m"]))
            else:
                out[key] = jax.tree.map(
                    lambda _: NamedSharding(self.mesh, P()), sub)
        return out

    def serve_shardings(self, params):
        """Weight-stationary (inference-TP) shardings for serving."""
        return tp_shardings(params, self.mesh)

    def serve_spec(self, *, page_size: int = 16, max_batch: int = 8,
                   max_seq_len: int | None = None,
                   prefill_chunk: int = 64,
                   offload_chunks: int | None = None) -> ServeSpec | None:
        """Paged-serving geometry from the memory model: bf16 weights and
        per-slot window rings are charged against the budget first; the
        paged block pool takes what's left, capped at the usable maximum
        ``max_batch × max_blocks_per_seq`` (blocks beyond every slot's
        worst case can never be handed out).  None for families without a
        paged decode path.

        Chunk offload (``offload_chunks``, default: the plan's) reuses
        ``offload_split``: only the resident fraction of a block is
        charged against HBM — the same rule the train activation model
        applies, so a KV byte is accounted device-side *or* host-side,
        never both."""
        per_tok, win_bytes = serve_kv_bytes(self.cfg)
        if per_tok is None:
            return None
        chunks = self.offload_chunks if offload_chunks is None \
            else offload_chunks
        max_seq = max_seq_len or self.seq_len or 4096
        max_blocks_per_seq = -(-max_seq // page_size)
        headroom = (self.memory_budget * SERVE_BUDGET_FRAC
                    - self.mem.get("n_params", 0) * HALF_BYTES_PER_PARAM
                    - max_batch * win_bytes)
        cap = max_batch * max_blocks_per_seq
        block_dev, _ = offload_split(per_tok * page_size, chunks)
        fit = int(headroom // max(block_dev, 1))
        num_blocks = max(min(fit, cap), max_blocks_per_seq)
        return ServeSpec(page_size=page_size, num_blocks=num_blocks,
                         max_blocks_per_seq=max_blocks_per_seq,
                         max_batch=max_batch, prefill_chunk=prefill_chunk,
                         paged_bytes_per_token=per_tok,
                         window_bytes=win_bytes)

    @property
    def packing_frac(self) -> float:
        """Fraction of the full causal band a packed stream attends
        (≈ mean_doc_len / seq_len) — the §4.5 cost model's ``packing``
        term.  1.0 when not packed (or shapes unknown)."""
        if not self.packed or not self.seq_len:
            return 1.0
        mean = self.mean_doc_len or self.seq_len
        return min(1.0, max(mean / self.seq_len, 1e-6))

    def batch_shardings(self, kind: str = "train"):
        """NamedShardings for a step's batch dict.  Train batches carry a
        leading (replicated) accumulation axis when ``grad_accum > 1``;
        packed plans add the ``doc_start`` boundary table (token-like)."""
        mesh, rt = self.mesh, self.rt
        lead = (None,) if kind == "train" and self.grad_accum > 1 else ()
        if kind == "decode":
            return {"tokens": NamedSharding(mesh, P(rt.batch_axes, None))}
        tok = NamedSharding(mesh, P(*lead, rt.batch_axes, SEQ_AXES))
        out = {"tokens": tok}
        if kind == "train":
            out["labels"] = out["positions"] = tok
            if self.packed:
                out["doc_start"] = tok
        if self.cfg.family == "encdec":
            out["frames"] = NamedSharding(
                mesh, P(*lead, rt.batch_axes, SEQ_AXES, None))
        return out

    def attn2d(self, *, causal: bool = True, zigzag: bool | None = None,
               window: int | None = None, softcap: float = 0.0,
               scale: float | None = None) -> Attn2DConfig:
        """The 2D-Attention grid config implied by this plan."""
        return attn2d_config(self.pc, impl=self.rt.impl, causal=causal,
                             zigzag=self.cfg.zigzag if zigzag is None
                             else zigzag, window=window, softcap=softcap,
                             scale=scale)

    def data_config(self, seq_len: int, global_batch: int,
                    zigzag: bool | None = None, **kw):
        """DataConfig consistent with this plan (cp, zigzag layout,
        microbatch grid) — the loader-side §4.4 post-processing.
        ``zigzag`` defaults to the plan's model-family decision.  Packed
        plans fill ``doc_len_range`` around ``mean_doc_len``."""
        from repro.data.pipeline import DataConfig
        cfg = self.cfg
        if zigzag is None:
            zigzag = cfg.zigzag and cfg.family in ("dense", "moe", "encdec")
        if self.packed and "doc_len_range" not in kw \
                and self.mean_doc_len is not None:
            # clamp: a plan tuned for a longer sequence may be reused at
            # a shorter one (resolve_tuned permits it with a note)
            m = min(self.mean_doc_len, seq_len)
            kw["doc_len_range"] = (max(2, m // 2), min(seq_len, 2 * m))
        return DataConfig(vocab=cfg.vocab, seq_len=seq_len,
                          global_batch=global_batch, cp=self.pc.cp,
                          zigzag=zigzag, grad_accum=self.grad_accum, **kw)

    def data_source(self, seq_len: int, global_batch: int, **kw):
        """The plan's data source: ``PackedLM`` for packed plans,
        ``SyntheticLM`` otherwise."""
        from repro.data.pipeline import PackedLM, SyntheticLM
        src = PackedLM if self.packed else SyntheticLM
        return src(self.data_config(seq_len, global_batch, **kw), self.cfg)

    # -- reporting ----------------------------------------------------------

    def leaf_extents(self) -> dict:
        """{top-level param key: sorted unique (extent, axes)} — the ZeRO
        degree actually applied per leaf class."""
        struct = _params_struct(self.cfg)
        out: dict[str, set] = {}
        for path, leaf in jax.tree_util.tree_flatten_with_path(struct)[0]:
            key = str(getattr(path[0], "key", path[0]))
            ext = leaf_extent(leaf.shape, self.mesh, self.zero_groups) \
                if self.zero_groups else (1, ())
            out.setdefault(key, set()).add(ext)
        return {k: sorted(v) for k, v in sorted(out.items())}

    def describe(self) -> str:
        """One table: mesh, placement, ZeRO extent per leaf class, remat,
        accumulation, per-device memory estimate."""
        cfg, pc, m = self.cfg, self.pc, self.mem
        minor = "head" if pc.placement == "head_first" else "inner"
        shape = "×".join(str(self.mesh.shape[a]) for a in MESH_AXES)
        lines = [
            f"ExecutionPlan: {cfg.name} [{cfg.family}] on "
            f"{self.mesh.size} devices",
            f"  mesh        {'×'.join(MESH_AXES)} = {shape}  "
            f"placement={pc.placement} ({minor} minor)",
            f"  parallel    dp={pc.dp} pods={pc.pods} hp={pc.hp} "
            f"cp={pc.cp} (outer={pc.cp_outer} × inner={pc.cp_inner})  "
            f"sp={pc.sp}",
            f"  batch       global_batch={self.global_batch} "
            f"seq_len={self.seq_len} grad_accum={self.grad_accum} "
            f"microbatch={m.get('microbatch')}",
            f"  attention   impl={self.rt.impl} zigzag={cfg.zigzag} "
            f"hp={pc.hp}×cp={pc.cp} 2D grid",
            f"  packing     {'on' if self.packed else 'off'}"
            + (f" mean_doc={self.mean_doc_len} "
               f"frac={self.packing_frac:.3f}" if self.packed else ""),
            f"  remat       {cfg.remat}",
            f"  zero        mode={self.zero_mode} "
            f"extent={m.get('zero_extent', 1)} "
            f"axes={self.zero_groups[0] if self.zero_groups else ()}",
        ]
        ext = self.leaf_extents()
        if ext:
            per = " ".join(
                f"{k}={'/'.join(str(e) for e, _ in v)}"
                for k, v in ext.items())
            lines.append(f"    leaf extents: {per}")
        lines.append(
            f"  memory/dev  params+opt={_fmt_bytes(m.get('state_dev', 0))} "
            f"bf16-copy={_fmt_bytes(m.get('half_dev', 0))} "
            f"acts≈{_fmt_bytes(m.get('act_dev', 0))} "
            f"total≈{_fmt_bytes(m.get('total_dev', 0))} "
            f"/ budget {_fmt_bytes(self.memory_budget)}")
        max_seq = m.get("max_seq_at_budget")
        lines.append(
            f"  offload     chunks={self.offload_chunks} "
            f"resident={offload_resident_frac(self.offload_chunks):.2f} "
            f"act_host={_fmt_bytes(m.get('act_host', 0))} "
            f"wire≈{m.get('offload_wire_s', 0) * 1e3:.1f}ms/step "
            f"max_seq@budget≈"
            f"{max_seq if max_seq is not None else 'n/a'}")
        lines.append(
            f"  ckpt        bytes/host="
            f"{_fmt_bytes(m.get('ckpt_bytes_host', 0))} "
            f"(state/{m.get('zero_extent', 1)}) "
            f"snapshot-stall≈{m.get('ckpt_stall_s', 0) * 1e3:.1f}ms "
            f"(write async)")
        sv = self.serve_spec()
        if sv is None:
            lines.append(f"  serve       paged=n/a (family={cfg.family})")
        else:
            pool = sv.num_blocks * sv.page_size * sv.paged_bytes_per_token
            lines.append(
                f"  serve       page={sv.page_size} "
                f"blocks={sv.num_blocks} "
                f"(pool={_fmt_bytes(pool)} kv/token="
                f"{_fmt_bytes(sv.paged_bytes_per_token)}) "
                f"max_batch={sv.max_batch} "
                f"max_seq={sv.max_blocks_per_seq * sv.page_size} "
                f"prefill_chunk={sv.prefill_chunk}")
        return "\n".join(lines)


def plan_memory(cfg, pc: ParallelConfig, *, grad_accum: int = 1,
                remat: str | None = None, zero: str = "auto",
                memory_budget_gb: float = 16.0,
                include_pod: bool = False,
                seq_len: int | None = None,
                global_batch: int | None = None,
                offload_chunks: int = 1,
                mesh=None):
    """The param+optimizer+activation memory model behind ``build_plan``.

    Runnable without devices: with ``mesh=None`` group extents come from
    the ``ParallelConfig`` shape alone (``_ShapeOnlyMesh``), which is how
    the PlanTuner (``repro/tune``) prunes candidate configurations at
    enumeration scale.  Returns ``(remat_policy, zero_mode, groups, mem)``
    where ``mem`` carries the per-device estimates plus the feasibility
    verdicts ``fits_state`` / ``fits``.

    ``offload_chunks > 1`` applies the FPDT chunk-pipeline split: only
    ``offload_resident_frac`` of the sequence-extensive bytes stay in HBM
    (``act_dev``; the rest is ``act_host``), in exchange for the PCIe
    wire time ``offload_wire_s`` of streaming chunks back per step.
    ``max_seq_at_budget`` is the longest trainable sequence the remaining
    headroom admits at this residency fraction (monotone in the budget).
    """
    pc.validate()
    assert grad_accum >= 1
    if global_batch is not None:
        assert global_batch % grad_accum == 0, (global_batch, grad_accum)
    shape = mesh if mesh is not None else _ShapeOnlyMesh(pc)

    budget = memory_budget_gb * 1e9
    n_params = _param_count(cfg)

    # hybrid-ZeRO extent from the param+optimizer memory model
    if zero == "auto":
        zero_mode, group, groups = choose_zero_mode(
            n_params, shape, budget, include_pod=include_pod)
    else:
        by_name = dict(ZERO_MODES)
        assert zero in by_name, (zero, sorted(by_name))
        zero_mode, group = zero, by_name[zero]
        smaller = tuple(g for _, g in ZERO_MODES
                        if g and _group_size(shape, g) <
                        max(_group_size(shape, group), 1))
        groups = ((group,) if group else ()) + tuple(reversed(smaller))
    extent = max(_group_size(shape, group), 1)
    state_dev = n_params * STATE_BYTES_PER_PARAM / extent
    half_dev = n_params * HALF_BYTES_PER_PARAM / extent

    # batch shardability + per-device tokens for the activation model
    assert offload_chunks >= 1, offload_chunks
    n_batch_dev = pc.pods * pc.dp
    batch_shardable = True
    microbatch = tokens_dev = None
    tokens_per_seq_unit = None
    if global_batch is not None:
        microbatch = global_batch // grad_accum
        batch_shardable = microbatch % n_batch_dev == 0
        div = (n_batch_dev if batch_shardable else 1) * pc.sp
        tokens_per_seq_unit = microbatch / div
        if seq_len is not None:
            tokens_dev = microbatch * seq_len / div

    # remat policy
    if remat == "auto":
        policy = choose_remat(cfg, budget, state_dev + half_dev,
                              tokens_dev) if tokens_dev is not None \
            else cfg.remat
    else:
        policy = remat or cfg.remat

    act_total = (tokens_dev or 0) * cfg.d_model * 2 \
        * ACT_UNITS[policy] * cfg.num_layers
    act_dev, act_host = offload_split(act_total, offload_chunks)

    # chunk-pipeline wire time: KV chunk j is re-fetched for every
    # q-chunk i >= j, so a full fwd (and again bwd) round streams
    # ≈ (C+1)/2 copies of the local K+V; q/out/lse/do staging adds ~4
    # one-shot tensors.  Copies overlap ring steps, but the wire bytes
    # are a hard PCIe floor the cost model trades against HBM freed.
    offload_wire_s = 0.0
    if offload_chunks > 1 and tokens_dev:
        kv_bytes = tokens_dev * cfg.d_model * 2 * 2          # K+V, bf16
        refetch = (offload_chunks + 1) / 2
        wire = (2 * refetch * kv_bytes + 4 * tokens_dev * cfg.d_model * 2) \
            * cfg.num_layers
        offload_wire_s = wire / OFFLOAD_WIRE_BYTES_PER_S

    total_dev = state_dev + half_dev + act_dev
    # longest trainable sequence the activation headroom admits at this
    # residency fraction (per device, at the plan's microbatch layout)
    max_seq_at_budget = None
    if tokens_per_seq_unit:
        per_seq_unit = tokens_per_seq_unit * cfg.d_model * 2 \
            * ACT_UNITS[policy] * cfg.num_layers \
            * offload_resident_frac(offload_chunks)
        headroom = max(budget - state_dev - half_dev, 0.0)
        max_seq_at_budget = int(headroom / max(per_seq_unit, 1e-9))
    # sharded-checkpoint footprint: each host serializes only its shards
    # of the fp32 master + Adam moments, so bytes/host (and the blocking
    # device→host snapshot stall) shrink with the ZeRO extent
    ckpt_host = n_params * STATE_BYTES_PER_PARAM / extent
    mem = {"n_params": n_params, "state_dev": state_dev,
           "half_dev": half_dev, "act_dev": act_dev,
           "act_host": act_host,
           "total_dev": total_dev,
           "offload_chunks": offload_chunks,
           "offload_wire_s": offload_wire_s,
           "max_seq_at_budget": max_seq_at_budget,
           "ckpt_bytes_host": ckpt_host,
           "ckpt_stall_s": ckpt_host / CKPT_D2H_BYTES_PER_S,
           "zero_extent": extent, "microbatch": microbatch,
           "batch_shardable": batch_shardable,
           "fits_state": state_dev + half_dev
           <= budget * STATE_BUDGET_FRAC,
           "fits": (state_dev + half_dev <= budget * STATE_BUDGET_FRAC
                    and total_dev <= budget)}
    return policy, zero_mode, groups, mem


def build_plan(cfg, pc: ParallelConfig | None = None, opt=None, *,
               devices=None, base_mesh: Mesh | None = None,
               impl: str | None = None, grad_accum: int | None = None,
               remat: str | None = None, zero: str | None = None,
               memory_budget_gb: float = 16.0,
               include_pod: bool = False,
               seq_len: int | None = None,
               global_batch: int | None = None,
               packed: bool = False,
               mean_doc_len: int | None = None,
               offload_chunks: int | None = None,
               tuned=None) -> ExecutionPlan:
    """Build the ExecutionPlan — the only place these decisions are made.

    * ``devices`` / ``base_mesh`` — flat device list (tests, single-host)
      or a production ``(pod, data, model)`` mesh to refine.
    * ``impl`` — attention impl; ``None`` auto-selects by backend.
    * ``remat`` — ``None`` keeps ``cfg.remat``; ``"auto"`` decides from
      the activation memory model (needs ``seq_len``+``global_batch``);
      an explicit policy overrides.
    * ``zero`` — ``None``/``"auto"`` picks the AMSP mode from the memory
      model; or force ``replica | dp | sp | dp_sp | pod_dp_sp``.
    * ``packed`` — packed-document training (``PackedLM`` batches with a
      ``doc_start`` boundary table, block-causal attention masking);
      attention families only.  ``mean_doc_len`` feeds the cost model's
      packing term and the data source's document-length range.
    * ``tuned`` — a ``repro.tune.TunedPlan`` (or any object with its
      fields): fills every knob the caller left unset (``None``) —
      ``pc``, ``grad_accum``, ``zero``, ``remat``, ``seq_len``,
      ``global_batch`` — so a persisted tuner winner rebuilds the exact
      plan with zero re-search.  Any explicitly passed value wins over
      the file.
    """
    from repro.train.optimizer import OptConfig
    if tuned is not None:
        if pc is None:
            pc = ParallelConfig(dp=tuned.dp, hp=tuned.hp,
                                cp_outer=tuned.cp_outer,
                                cp_inner=tuned.cp_inner, pods=tuned.pods,
                                placement=tuned.placement)
        if grad_accum is None:
            grad_accum = tuned.grad_accum
        if remat is None:
            remat = tuned.remat
        if zero is None:
            zero = tuned.zero
        if seq_len is None:
            seq_len = tuned.seq_len
        if global_batch is None:
            global_batch = tuned.global_batch
        if offload_chunks is None:
            offload_chunks = getattr(tuned, "offload_chunks", 1)
    grad_accum = 1 if grad_accum is None else grad_accum
    offload_chunks = 1 if offload_chunks is None else offload_chunks
    zero = zero or "auto"
    pc = pc or ParallelConfig()
    opt = opt or OptConfig()
    pc.validate()
    if packed:
        assert cfg.family in ("dense", "moe"), \
            f"packed training needs an attention family, got {cfg.family} " \
            "(SSM state has no per-document reset)"

    mesh = refine_mesh(base_mesh, pc) if base_mesh is not None \
        else make_mesh(pc, devices=devices)
    if impl is None:
        impl = "auto" if jax.default_backend() == "tpu" else "ref"

    policy, zero_mode, groups, mem = plan_memory(
        cfg, pc, grad_accum=grad_accum, remat=remat, zero=zero,
        memory_budget_gb=memory_budget_gb, include_pod=include_pod,
        seq_len=seq_len, global_batch=global_batch,
        offload_chunks=offload_chunks, mesh=mesh)
    if policy != cfg.remat:
        cfg = dataclasses.replace(cfg, remat=policy)

    rt = Runtime(mesh=mesh, pc=pc, impl=impl,
                 batch_axes=BATCH_AXES if mem["batch_shardable"] else ())
    return ExecutionPlan(cfg=cfg, pc=pc, opt=opt, mesh=mesh, rt=rt,
                         grad_accum=grad_accum, zero_mode=zero_mode,
                         zero_groups=groups,
                         memory_budget=memory_budget_gb * 1e9,
                         seq_len=seq_len, global_batch=global_batch,
                         packed=packed, mean_doc_len=mean_doc_len,
                         offload_chunks=offload_chunks, mem=mem)
