"""Runtime context threaded through model code: mesh + parallel layout."""
from __future__ import annotations

import dataclasses

from jax.sharding import Mesh, PartitionSpec as P

from repro.core.topology import BATCH_AXES, SEQ_AXES, ParallelConfig


@dataclasses.dataclass(frozen=True)
class Runtime:
    mesh: Mesh
    pc: ParallelConfig
    impl: str = "auto"          # attention kernel impl (auto/pallas/ref/...)
    #: axes the batch dim shards over; () when global_batch < dp (e.g. the
    #: B=1 long-context decode shape)
    batch_axes: tuple = BATCH_AXES

    def act_spec(self, *trailing) -> P:
        """(B, S, ...) activation spec: B over batch axes, S over sp axes."""
        return P(self.batch_axes, SEQ_AXES, *trailing)

    def constrain(self, x, *trailing):
        import jax
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, self.act_spec(*trailing)))
