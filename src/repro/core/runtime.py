"""Runtime context threaded through model code: mesh + parallel layout,
plus the version-portable ``shard_map`` entry point every module shares."""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.topology import BATCH_AXES, SEQ_AXES, ParallelConfig


def shard_map_compat(f, mesh, in_specs, out_specs):
    """``shard_map`` across jax versions.

    Newer jax exposes ``jax.shard_map`` (whose replication check is spelled
    ``check_vma``); older versions either lack the top-level binding
    entirely (``AttributeError``) or spell the flag ``check_rep`` — fall
    through to ``jax.experimental.shard_map`` in both cases.

    NOTE: the legacy module gives grad residuals worst-case dim-0
    shardings, which rejects 0-d residuals (its scalar promotion misses
    some) — shard-mapped code should carry (1,)-shaped accumulators
    instead of scalars (see ``models/model.py::chunked_xent``).
    """
    try:
        sm = jax.shard_map
    except AttributeError:
        from jax.experimental.shard_map import shard_map as sm
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)
    try:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    except TypeError:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def axis_size_compat(axis) -> "jax.Array | int":
    """``lax.axis_size`` across jax versions (older jax lacks it; the
    psum of a constant 1 is the portable spelling)."""
    from jax import lax
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis)
    return lax.psum(1, axis)


@dataclasses.dataclass(frozen=True)
class Runtime:
    mesh: Mesh
    pc: ParallelConfig
    impl: str = "auto"          # attention kernel impl (auto/pallas/ref/...)
    #: axes the batch dim shards over; () when global_batch < dp (e.g. the
    #: B=1 long-context decode shape)
    batch_axes: tuple = BATCH_AXES

    def act_spec(self, *trailing) -> P:
        """(B, S, ...) activation spec: B over batch axes, S over sp axes."""
        return P(self.batch_axes, SEQ_AXES, *trailing)

    def constrain(self, x, *trailing):
        import jax
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, self.act_spec(*trailing)))
