"""Zigzag sequence layout for causal load balance (paper §2.3/§4.4).

Context rank ``r`` of ``cp`` owns the logical chunks ``(r, 2cp-1-r)`` so
that every ring step performs the same amount of unmasked work.  The data
pipeline permutes tokens/labels/positions once per batch (the paper's
"post-processing function within the data loader"); attention masks inside
the ring are expressed in logical chunk ids (see attention2d.py).

``physical`` order = what lives contiguously in the sharded S dimension;
``logical`` order = real token order.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np


@functools.lru_cache(maxsize=None)
def zigzag_indices(s: int, cp: int) -> np.ndarray:
    """perm[physical_pos] = logical_pos  (length S)."""
    if cp == 1:
        return np.arange(s)
    assert s % (2 * cp) == 0, (s, cp)
    c = s // (2 * cp)
    out = np.empty(s, dtype=np.int64)
    for r in range(cp):
        lo = r * c
        hi = (2 * cp - 1 - r) * c
        base = r * 2 * c
        out[base:base + c] = np.arange(lo, lo + c)
        out[base + c:base + 2 * c] = np.arange(hi, hi + c)
    return out


@functools.lru_cache(maxsize=None)
def zigzag_inverse(s: int, cp: int) -> np.ndarray:
    """inv[logical_pos] = physical_pos."""
    idx = zigzag_indices(s, cp)
    inv = np.empty_like(idx)
    inv[idx] = np.arange(s)
    return inv


def to_zigzag(x, cp: int, axis: int = 1):
    """Logical -> physical layout along ``axis``."""
    if cp == 1:
        return x
    return jnp.take(x, jnp.asarray(zigzag_indices(x.shape[axis], cp)),
                    axis=axis)


def from_zigzag(x, cp: int, axis: int = 1):
    """Physical -> logical layout along ``axis``."""
    if cp == 1:
        return x
    return jnp.take(x, jnp.asarray(zigzag_inverse(x.shape[axis], cp)),
                    axis=axis)


def zigzag_position_ids(s: int, cp: int) -> np.ndarray:
    """Logical position of every physical slot (for rotary embeddings)."""
    return zigzag_indices(s, cp)
