"""2D-Attention: head-parallel × context-parallel distributed attention.

The paper's core mechanism (LoongTrain §4), TPU-native:

* **SeqAlltoAll** (Ulysses): ``jax.lax.all_to_all`` over the ``head`` mesh
  axis redistributes Q/K/V from ``(S/d_sp sequence, all heads)`` to
  ``(S/d_cp sequence, H/d_hp heads)`` and back.
* **KV replication** (paper §4.2): when ``d_hp > H_kv`` the KV heads are
  replicated *before* the all-to-all; the replica-gradient aggregation of the
  backward pass falls out of JAX's transpose of ``jnp.repeat``.
* **Double-Ring-Attention** (paper §4.3, Algorithm 2): the context group is
  factored into ``outer × inner`` mesh axes.  KV chunks rotate with
  ``jax.lax.ppermute`` — inner ring every micro-step, outer ring once per
  outer step, issued *before* the inner loop so XLA's latency-hiding
  scheduler overlaps it with the whole inner round (the paper's prefetch).
  Two concurrent ppermutes on distinct mesh axes travel on distinct ICI
  torus dimensions — the TPU analogue of "use all NICs".
* **Zigzag causal load balance**: context rank ``i`` owns logical sequence
  chunks ``(i, 2·cp−1−i)`` (the data pipeline pre-permutes tokens, paper
  §4.4's loader post-processing).  Every ring step then computes exactly two
  C×C sub-blocks per rank:

      j < i : whole-Q × K_lo        (both full)
      j = i : causal diagonal       (two causal halves + one full)
      j > i : Q_hi × whole-K        (both full)

  so per-step FLOPs are balanced and ≈ useful FLOPs.  All three cases are
  *one* kernel call parameterized by the scalar pair ``(i, j)`` through a
  ``BandMask``: the kernel's logical-position masking plus block-skip
  reproduces the case split internally, so there is no ``lax.cond`` branch
  pair, no duplicated branch HLO, and no zero-padding/concatenate traffic
  around the half-chunk cases.
* The ring is one ``jax.custom_vjp`` unit: forward accumulates (out, lse)
  with the flash combine rule; backward re-runs the ring, accumulating dq
  locally while dk/dv ride around the rings *with* their KV chunk and arrive
  home after a full cycle.

Everything here is the *per-shard* program (runs under ``shard_map``);
``attention_2d`` is the global-array entry point.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.runtime import shard_map_compat as _shard_map
from repro.core.topology import (AXIS_HP, AXIS_INNER, AXIS_OUTER, BATCH_AXES,
                                 SEQ_AXES)
from repro.core.zigzag import from_zigzag, to_zigzag
from repro.kernels.ops import flash_attention, flash_bwd_chunk, flash_fwd_chunk
from repro.kernels.ref import BandMask, combine_pair


class Attn2DConfig(NamedTuple):
    """Static 2D-Attention configuration (hashable)."""
    hp: int = 1
    n_out: int = 1            # outer ring size (d_cp / w)
    w: int = 1                # inner ring size (paper's w)
    causal: bool = True
    zigzag: bool = True       # False: contiguous chunks (hybrid/SSM models)
    window: int | None = None
    softcap: float = 0.0
    scale: float | None = None
    impl: str = "auto"
    axis_hp: str = AXIS_HP
    axis_outer: str = AXIS_OUTER
    axis_inner: str = AXIS_INNER

    @property
    def cp(self) -> int:
        return self.n_out * self.w


def attn2d_config(pc, *, impl: str, causal: bool = True,
                  zigzag: bool = True, window: int | None = None,
                  softcap: float = 0.0,
                  scale: float | None = None) -> Attn2DConfig:
    """The one place a ``ParallelConfig`` becomes an ``Attn2DConfig``
    (used by ``core/plan.py`` and the model attention blocks)."""
    return Attn2DConfig(hp=pc.hp, n_out=pc.cp_outer, w=pc.cp_inner,
                        causal=causal, zigzag=zigzag, window=window,
                        softcap=softcap, scale=scale, impl=impl)


class RingConfig(NamedTuple):
    """Static ring configuration (the custom_vjp nondiff arg)."""
    n_out: int
    w: int
    causal: bool
    zigzag: bool
    window: int | None
    softcap: float
    scale: float
    impl: str
    axis_outer: str
    axis_inner: str

    @property
    def cp(self) -> int:
        return self.n_out * self.w


def _shift(x, axis: str, size: int):
    """Ring ppermute: every rank sends to (r+1) % size, receives from r-1."""
    if size == 1:
        return x
    return lax.ppermute(x, axis, [(r, (r + 1) % size) for r in range(size)])


def _ring_indices(cfg: RingConfig):
    i_out = lax.axis_index(cfg.axis_outer)
    i_in = lax.axis_index(cfg.axis_inner)
    return i_out, i_in, i_out * cfg.w + i_in


def _visiting(cfg: RingConfig, i_out, i_in, o: int, t: int):
    """Global cp index of the KV chunk visiting this rank at step (o, t)."""
    j_out = (i_out - o) % cfg.n_out
    j_in = (i_in - t) % cfg.w
    return j_out * cfg.w + j_in


def _kw(cfg: RingConfig):
    return dict(softcap=cfg.softcap, scale=cfg.scale, impl=cfg.impl)


# ---------------------------------------------------------------------------
# Ring forward
# ---------------------------------------------------------------------------

def _step_band(cfg: RingConfig, i, j, s_loc: int, qb=0, kb=0) -> BandMask:
    """The (i, j) ring-step mask as a BandMask over the full local shapes.

    ``i``/``j`` are traced rank indices; the offsets land in the kernels as
    scalar-prefetch operands, so the case split (j<i full, j=i diagonal,
    j>i empty/half) happens inside one kernel call via logical-position
    masking + block skip — no ``lax.cond`` branch pair.

    ``qb``/``kb`` are global sequence-chunk bases (the FPDT chunk pipeline
    runs this same ring once per chunk pair; each side's logical positions
    shift by its chunk start).  The resident path passes 0/0.
    """
    if cfg.zigzag:
        band = BandMask.zigzag(i, j, s_loc // 2, cfg.cp)
    else:
        # Contiguous chunks (no causal load balance): chunk r = cp rank r.
        # Used by hybrid/SSM models whose recurrent layers need contiguous
        # sequence shards; the paper's balanced layout needs the zigzag data
        # permutation which those layers cannot tolerate.  Absolute offsets
        # on both sides (not the relative ``(i-j)·s_loc`` single-sided form)
        # keep packed-document doc-start comparisons — global positions —
        # correct; causal/window masking only sees the difference, which is
        # unchanged.
        band = BandMask(i * s_loc, i * s_loc, j * s_loc, j * s_loc, 0, 0)
    if isinstance(qb, int) and isinstance(kb, int) and qb == 0 and kb == 0:
        return band           # resident path: skip the no-op adds
    return band._replace(q_off_lo=band.q_off_lo + qb,
                         q_off_hi=band.q_off_hi + qb,
                         k_off_lo=band.k_off_lo + kb,
                         k_off_hi=band.k_off_hi + kb)


def _step_fwd(q, kc, vc, doc, o: int, t: int, i_out, i_in, i,
              cfg: RingConfig, qb=0, kb=0):
    """Partial (out, lse) of local q against the visiting KV chunk pair.

    ``doc`` (packed documents) is the *local* per-row doc-start table: it
    is q-side data, so it stays put while KV rotates — the band supplies
    the visiting chunk's logical positions, and the kernel compares them
    against the stationary doc starts.  No per-step translation needed.
    """
    kw = _kw(cfg)
    if not cfg.causal:
        return flash_fwd_chunk(q, kc, vc, causal=False, **kw)
    j = _visiting(cfg, i_out, i_in, o, t)
    return flash_fwd_chunk(q, kc, vc, causal=True, window=cfg.window,
                           band=_step_band(cfg, i, j, q.shape[1], qb, kb),
                           q_doc_start=doc, **kw)


def _ring_fwd(q, k, v, doc, cfg: RingConfig, qb=0, kb=0):
    i_out, i_in, i = _ring_indices(cfg)
    acc_o = None
    acc_l = None
    k0, v0 = k, v
    for o in range(cfg.n_out):
        nxt_outer = None
        if o < cfg.n_out - 1:
            # Outer prefetch (Alg. 2 line 3): issued before the inner loop so
            # it overlaps the whole inner round.
            nxt_outer = (_shift(k0, cfg.axis_outer, cfg.n_out),
                         _shift(v0, cfg.axis_outer, cfg.n_out))
        kc, vc = k0, v0
        for t in range(cfg.w):
            nxt_inner = None
            if t < cfg.w - 1:
                nxt_inner = (_shift(kc, cfg.axis_inner, cfg.w),
                             _shift(vc, cfg.axis_inner, cfg.w))
            po, pl_ = _step_fwd(q, kc, vc, doc, o, t, i_out, i_in, i, cfg,
                                qb, kb)
            if acc_o is None:
                acc_o, acc_l = po.astype(jnp.float32), pl_
            else:
                acc_o, acc_l = combine_pair(acc_o, acc_l, po, pl_)
            if nxt_inner is not None:
                kc, vc = nxt_inner
        if nxt_outer is not None:
            k0, v0 = nxt_outer
    return acc_o.astype(q.dtype), acc_l


# ---------------------------------------------------------------------------
# Ring backward
# ---------------------------------------------------------------------------

def _step_bwd(q, kc, vc, out, lse, do, doc, o: int, t: int, i_out, i_in, i,
              cfg: RingConfig, qb=0, kb=0):
    """(dq_part, dk_part, dv_part) for the KV chunk visiting at (o, t).

    ``out``/``lse`` are the final combined values (global softmax), so each
    step's contribution is exact and linear.
    """
    kw = _kw(cfg)
    if not cfg.causal:
        return flash_bwd_chunk(q, kc, vc, out, lse, do, causal=False, **kw)
    j = _visiting(cfg, i_out, i_in, o, t)
    return flash_bwd_chunk(q, kc, vc, out, lse, do, causal=True,
                           window=cfg.window,
                           band=_step_band(cfg, i, j, q.shape[1], qb, kb),
                           q_doc_start=doc, **kw)


def _ring_bwd(q, k, v, out, lse, do, doc, cfg: RingConfig, qb=0, kb=0):
    i_out, i_in, i = _ring_indices(cfg)
    dq = jnp.zeros(q.shape, jnp.float32)
    k0, v0 = k, v
    dk0 = jnp.zeros(k.shape, jnp.float32)
    dv0 = jnp.zeros(v.shape, jnp.float32)
    for o in range(cfg.n_out):
        kc, vc, dkc, dvc = k0, v0, dk0, dv0
        for t in range(cfg.w):
            dq_p, dk_p, dv_p = _step_bwd(q, kc, vc, out, lse, do, doc, o, t,
                                         i_out, i_in, i, cfg, qb, kb)
            dq = dq + dq_p.astype(jnp.float32)
            dkc = dkc + dk_p.astype(jnp.float32)
            dvc = dvc + dv_p.astype(jnp.float32)
            # dk/dv ride the inner ring with their chunk; the last rotation
            # completes the inner cycle so the chunk grads are home (within
            # this outer round) before the outer hop.
            last = (t == cfg.w - 1) and (o == cfg.n_out - 1)
            if not last:
                kc = _shift(kc, cfg.axis_inner, cfg.w)
                vc = _shift(vc, cfg.axis_inner, cfg.w)
            dkc = _shift(dkc, cfg.axis_inner, cfg.w)
            dvc = _shift(dvc, cfg.axis_inner, cfg.w)
        # Outer hop: the visiting set (with its accumulated grads) moves on;
        # after n_out hops every chunk's grads are back at their owner.
        if o < cfg.n_out - 1:
            k0 = _shift(kc, cfg.axis_outer, cfg.n_out)
            v0 = _shift(vc, cfg.axis_outer, cfg.n_out)
        dk0 = _shift(dkc, cfg.axis_outer, cfg.n_out)
        dv0 = _shift(dvc, cfg.axis_outer, cfg.n_out)
    return dq.astype(q.dtype), dk0.astype(k.dtype), dv0.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def ring_attention(q, k, v, doc, cfg: RingConfig):
    """Double-ring zigzag attention over the local (post-AlltoAll) shards.

    q: (b, S/cp, Hq/hp, d);  k/v: (b, S/cp, Hkv_eff/hp, d);
    doc: None, or the local (b, S/cp) int32 per-row doc-start table
    (packed documents — integer data, zero cotangent).
    """
    out, _ = _ring_fwd(q, k, v, doc, cfg)
    return out


def _ring_vjp_fwd(q, k, v, doc, cfg: RingConfig):
    out, lse = _ring_fwd(q, k, v, doc, cfg)
    return out, (q, k, v, doc, out, lse)


def _ring_vjp_bwd(cfg: RingConfig, res, do):
    q, k, v, doc, out, lse = res
    dq, dk, dv = _ring_bwd(q, k, v, out, lse, do, doc, cfg)
    d_doc = None if doc is None else np.zeros(doc.shape, jax.dtypes.float0)
    return dq, dk, dv, d_doc


ring_attention.defvjp(_ring_vjp_fwd, _ring_vjp_bwd)


# ---------------------------------------------------------------------------
# SeqAlltoAll + public API
# ---------------------------------------------------------------------------

def attention_2d_local(q, k, v, cfg: Attn2DConfig, doc_start=None):
    """Per-shard 2D-Attention (call under shard_map).

    q: (b, S/d_sp, Hq, d);  k/v: (b, S/d_sp, Hkv, d).  Returns q-shaped out.

    ``doc_start``: local (b, S/d_sp) int32 per-row doc-start table for
    packed documents.  The SeqAlltoAll redistributes *heads*, so the
    boundary table has nothing to split — it is all-gathered over the
    head axis along the sequence dim (int32/token: ~0.25% of one tensor's
    a2a bytes), after which every cp rank holds the table for exactly the
    S/d_cp rows its post-AlltoAll q holds.
    """
    b, s_loc, hq, dh = q.shape
    hkv = k.shape[2]
    scale = cfg.scale if cfg.scale is not None else 1.0 / (dh ** 0.5)
    if doc_start is not None:
        assert cfg.causal, "packed documents require causal attention"

    if cfg.hp > hkv:
        # Paper §4.2: replicate KV heads to d_hp before the SeqAlltoAll.
        assert cfg.hp % hkv == 0, (cfg.hp, hkv)
        rep = cfg.hp // hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)

    if cfg.hp > 1:
        assert hq % cfg.hp == 0, (hq, cfg.hp)
        q = lax.all_to_all(q, cfg.axis_hp, 2, 1, tiled=True)
        k = lax.all_to_all(k, cfg.axis_hp, 2, 1, tiled=True)
        v = lax.all_to_all(v, cfg.axis_hp, 2, 1, tiled=True)
        if doc_start is not None:
            doc_start = lax.all_gather(doc_start, cfg.axis_hp, axis=1,
                                       tiled=True)

    if cfg.cp == 1:
        out = flash_attention(q, k, v, causal=cfg.causal, window=cfg.window,
                              softcap=cfg.softcap, scale=scale,
                              q_doc_start=doc_start, impl=cfg.impl)
    else:
        rcfg = RingConfig(n_out=cfg.n_out, w=cfg.w, causal=cfg.causal,
                          zigzag=cfg.zigzag and cfg.causal,
                          window=cfg.window, softcap=cfg.softcap,
                          scale=scale, impl=cfg.impl,
                          axis_outer=cfg.axis_outer,
                          axis_inner=cfg.axis_inner)
        out = ring_attention(q, k, v, doc_start, rcfg)

    if cfg.hp > 1:
        out = lax.all_to_all(out, cfg.axis_hp, 1, 2, tiled=True)
    return out


def attention_2d(q, k, v, *, mesh, cfg: Attn2DConfig, doc_start=None):
    """Global-array 2D-Attention: q (B, S, Hq, d), k/v (B, S, Hkv, d).

    B is sharded over the batch axes, S over the sp axes (the zigzag
    data-layout contract — see data/pipeline.py).  ``doc_start``
    (optional, (B, S) int32): per-token logical document starts in the
    same physical layout as q — packed-document block-causal masking.
    """
    spec = P(BATCH_AXES, SEQ_AXES, None, None)
    if doc_start is None:
        f = _shard_map(functools.partial(attention_2d_local, cfg=cfg),
                       mesh, (spec, spec, spec), spec)
        return f(q, k, v)
    spec_d = P(BATCH_AXES, SEQ_AXES)
    f = _shard_map(
        lambda q, k, v, d: attention_2d_local(q, k, v, cfg, doc_start=d),
        mesh, (spec, spec, spec, spec_d), spec)
    return f(q, k, v, jnp.asarray(doc_start, jnp.int32))


# ---------------------------------------------------------------------------
# Sequence-chunk pipelining with host KV offload (FPDT, arxiv 2408.16978)
# ---------------------------------------------------------------------------
#
# The resident path above holds the entire local sequence in HBM, so max
# trainable context is capped by device memory regardless of mesh size.
# The chunked path splits the *global* sequence into C chunks, keeps only
# the active (and prefetched) chunks in HBM via an OffloadManager, and
# runs the same double-ring/Ulysses machinery once per causal chunk pair
# (i, j<=i).  The pair kernels are the resident ones: the only change is
# that each side's BandMask logical positions shift by its chunk base
# (qb = i·Sc, kb = j·Sc), so zigzag, packed-document doc starts (global
# positions — boundaries straddling chunk edges included), GQA folding
# and block skip all fall out unchanged.  Per-pair FLOPs match the causal
# half at chunk granularity: pair j<i is all-visible, j=i is the ordinary
# zigzag diagonal.
#
# Host staging is opaque to jax.grad (tracers cannot cross np.asarray), so
# the driver is an explicit forward + manual vjp: a host Python loop over
# two jitted shard_map programs (one forward pair, one backward pair),
# qb/kb passed as traced int32 scalars so a single compile serves every
# pair.  Forward accumulates (out, lse) partials with the flash combine
# rule; backward accumulates dq on device and sends dk/dv home to host
# fp32 accumulators chunk by chunk.

def _chunk_ring_cfg(cfg: Attn2DConfig, dh: int) -> RingConfig:
    scale = cfg.scale if cfg.scale is not None else 1.0 / (dh ** 0.5)
    return RingConfig(n_out=cfg.n_out, w=cfg.w, causal=True,
                      zigzag=cfg.zigzag, window=None, softcap=cfg.softcap,
                      scale=scale, impl=cfg.impl, axis_outer=cfg.axis_outer,
                      axis_inner=cfg.axis_inner)


def _chunk_pair_fwd_local(q, k, v, doc, qb, kb, cfg: Attn2DConfig):
    """Per-shard (out, lse) of q-chunk (base qb) against kv-chunk (kb)."""
    dh = q.shape[-1]
    hkv = k.shape[2]
    rcfg = _chunk_ring_cfg(cfg, dh)
    if cfg.hp > hkv:
        rep = cfg.hp // hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    if cfg.hp > 1:
        q = lax.all_to_all(q, cfg.axis_hp, 2, 1, tiled=True)
        k = lax.all_to_all(k, cfg.axis_hp, 2, 1, tiled=True)
        v = lax.all_to_all(v, cfg.axis_hp, 2, 1, tiled=True)
        if doc is not None:
            doc = lax.all_gather(doc, cfg.axis_hp, axis=1, tiled=True)
    out, lse = _ring_fwd(q, k, v, doc, rcfg, qb, kb)
    if cfg.hp > 1:
        out = lax.all_to_all(out, cfg.axis_hp, 1, 2, tiled=True)
        lse = lax.all_to_all(lse, cfg.axis_hp, 2, 1, tiled=True)
    return out, lse


def _chunk_pair_bwd_local(q, k, v, out, lse, do, doc, qb, kb,
                          cfg: Attn2DConfig):
    """Per-shard (dq, dk, dv) contribution of one (q-chunk, kv-chunk) pair.

    ``out``/``lse`` are the chunk's *final* combined values, so every
    pair's contribution is exact and linear (same argument as the ring
    backward's per-step decomposition).
    """
    dh = q.shape[-1]
    hkv = k.shape[2]
    rcfg = _chunk_ring_cfg(cfg, dh)
    rep = cfg.hp // hkv if cfg.hp > hkv else 1
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    if cfg.hp > 1:
        q, k, v, out, do = (lax.all_to_all(x, cfg.axis_hp, 2, 1, tiled=True)
                            for x in (q, k, v, out, do))
        lse = lax.all_to_all(lse, cfg.axis_hp, 1, 2, tiled=True)
        if doc is not None:
            doc = lax.all_gather(doc, cfg.axis_hp, axis=1, tiled=True)
    dq, dk, dv = _ring_bwd(q, k, v, out, lse, do, doc, rcfg, qb, kb)
    if cfg.hp > 1:
        dq, dk, dv = (lax.all_to_all(x, cfg.axis_hp, 1, 2, tiled=True)
                      for x in (dq, dk, dv))
    if rep > 1:
        bb, ss, _, dd = dk.shape
        # jnp.repeat is consecutive, so replica grads group-sum by reshape.
        dk = dk.reshape(bb, ss, hkv, rep, dd).sum(3)
        dv = dv.reshape(bb, ss, hkv, rep, dd).sum(3)
    return dq, dk, dv


@functools.lru_cache(maxsize=32)
def _chunk_pair_fns(mesh, cfg: Attn2DConfig, has_doc: bool):
    """(fwd, bwd) jitted global-array pair programs for (mesh, cfg).

    One compile serves all (i, j) pairs: the chunk bases ride in as traced
    int32 scalars (they land in the kernels as scalar-prefetch operands,
    exactly like the ring's rank indices)."""
    spec = P(BATCH_AXES, SEQ_AXES, None, None)
    spec_l = P(BATCH_AXES, None, SEQ_AXES)
    spec_d = P(BATCH_AXES, SEQ_AXES)
    sc = P()
    if has_doc:
        fwd = _shard_map(
            lambda q, k, v, d, qb, kb:
                _chunk_pair_fwd_local(q, k, v, d, qb, kb, cfg),
            mesh, (spec, spec, spec, spec_d, sc, sc), (spec, spec_l))
        bwd = _shard_map(
            lambda q, k, v, o, l, g, d, qb, kb:
                _chunk_pair_bwd_local(q, k, v, o, l, g, d, qb, kb, cfg),
            mesh, (spec, spec, spec, spec, spec_l, spec, spec_d, sc, sc),
            (spec, spec, spec))
    else:
        fwd = _shard_map(
            lambda q, k, v, qb, kb:
                _chunk_pair_fwd_local(q, k, v, None, qb, kb, cfg),
            mesh, (spec, spec, spec, sc, sc), (spec, spec_l))
        bwd = _shard_map(
            lambda q, k, v, o, l, g, qb, kb:
                _chunk_pair_bwd_local(q, k, v, o, l, g, None, qb, kb, cfg),
            mesh, (spec, spec, spec, spec, spec_l, spec, sc, sc),
            (spec, spec, spec))
    return jax.jit(fwd), jax.jit(bwd)


@jax.jit
def _combine_chunks(oa, la, ob, lb):
    return combine_pair(oa, la, ob, lb)


@jax.jit
def _acc(a, b):
    return a + b


class ChunkedAttention:
    """FPDT-style sequence-chunk pipelined 2D-Attention with KV offload.

    Inputs and outputs are in *logical* token order over the full
    sequence; the per-chunk zigzag layout is applied internally (each
    chunk is independently balanced over the cp ranks, so the resident
    ring kernels apply per pair unchanged).  Causal, full-context only
    (``window`` needs no offload — its KV footprint is already bounded).

    The manager's HBM budget covers staged chunk residency; with the
    double-buffer schedule the peak is the active pair plus the
    prefetched next K/V (≈ q + 2·(k+v) chunk shards on the forward,
    plus out/lse/do on the backward).

    Usage::

        ca = ChunkedAttention(mesh, cfg, chunks=8)
        out = ca.forward(q, k, v)          # logical order
        dq, dk, dv = ca.vjp(d_out)         # manual vjp (host loop is
                                           # opaque to jax.grad)
    """

    def __init__(self, mesh, cfg: Attn2DConfig, *, chunks: int,
                 offload=None):
        assert cfg.causal, "chunk pipelining is causal-only"
        assert cfg.window is None, \
            "sliding-window KV is already bounded; no offload needed"
        assert chunks >= 1, chunks
        if offload is None:
            from repro.runtime.offload import OffloadManager
            offload = OffloadManager()
        self.mesh, self.cfg, self.chunks = mesh, cfg, chunks
        self.mgr = offload
        self._docs = None
        self._sc = None
        self._dtypes = None

    # -- layout helpers ----------------------------------------------------

    def _lay(self, x):
        return to_zigzag(x, self.cfg.cp) if self.cfg.zigzag else x

    def _unlay(self, x):
        return from_zigzag(x, self.cfg.cp) if self.cfg.zigzag else x

    def _stage(self, name: str, x, sc: int):
        """Slice ``x`` into chunks, per-chunk zigzag, snapshot to host."""
        for i in range(self.chunks):
            self.mgr.put((name, i), self._lay(x[:, i * sc:(i + 1) * sc]))

    # -- forward -----------------------------------------------------------

    def forward(self, q, k, v, doc_start=None):
        C, cp = self.chunks, self.cfg.cp
        S = q.shape[1]
        assert S % C == 0, (S, C)
        sc = S // C
        if self.cfg.zigzag and cp > 1:
            assert sc % (2 * cp) == 0, \
                f"chunk len {sc} must split into 2·cp={2 * cp} zigzag " \
                f"sub-chunks"
        self._sc = sc
        self._dtypes = (q.dtype, k.dtype, v.dtype)
        fwd, _ = _chunk_pair_fns(self.mesh, self.cfg, doc_start is not None)
        for name, x in (("q", q), ("k", k), ("v", v)):
            self._stage(name, x, sc)
        self._docs = None
        if doc_start is not None:
            d = jnp.asarray(doc_start, jnp.int32)
            self._docs = [self._lay(d[:, i * sc:(i + 1) * sc])
                          for i in range(C)]
        mgr, outs = self.mgr, []
        for i in range(C):
            mgr.prefetch(("q", i))
            qi = mgr.get(("q", i))
            di = () if self._docs is None else (self._docs[i],)
            mgr.prefetch(("k", 0))
            mgr.prefetch(("v", 0))
            acc_o = acc_l = None
            for j in range(i + 1):
                if j < i:   # double buffer: next fetch overlaps this pair
                    mgr.prefetch(("k", j + 1))
                    mgr.prefetch(("v", j + 1))
                kj, vj = mgr.get(("k", j)), mgr.get(("v", j))
                po, pl_ = fwd(qi, kj, vj, *di,
                              jnp.asarray(i * sc, jnp.int32),
                              jnp.asarray(j * sc, jnp.int32))
                if acc_o is None:
                    acc_o, acc_l = po.astype(jnp.float32), pl_
                else:
                    acc_o, acc_l = _combine_chunks(acc_o, acc_l, po, pl_)
                mgr.release(("k", j))
                mgr.release(("v", j))
            out_i = acc_o.astype(q.dtype)
            mgr.put(("o", i), out_i)       # saved residuals for the vjp
            mgr.put(("l", i), acc_l)
            mgr.release(("q", i))
            outs.append(self._unlay(out_i))
        return jnp.concatenate(outs, axis=1)

    # -- backward ----------------------------------------------------------

    def vjp(self, do):
        """(dq, dk, dv) in logical order given the output cotangent."""
        assert self._sc is not None, "forward() first"
        C, sc = self.chunks, self._sc
        qdt, kdt, vdt = self._dtypes
        _, bwd = _chunk_pair_fns(self.mesh, self.cfg, self._docs is not None)
        mgr = self.mgr
        self._stage("g", do, sc)
        dqs = []
        for i in range(C):
            for key in (("q", i), ("g", i), ("o", i), ("l", i)):
                mgr.prefetch(key)
            qi, gi = mgr.get(("q", i)), mgr.get(("g", i))
            oi, li = mgr.get(("o", i)), mgr.get(("l", i))
            di = () if self._docs is None else (self._docs[i],)
            mgr.prefetch(("k", 0))
            mgr.prefetch(("v", 0))
            dq_i = None
            for j in range(i + 1):
                if j < i:
                    mgr.prefetch(("k", j + 1))
                    mgr.prefetch(("v", j + 1))
                kj, vj = mgr.get(("k", j)), mgr.get(("v", j))
                dq_p, dk_p, dv_p = bwd(qi, kj, vj, oi, li, gi, *di,
                                       jnp.asarray(i * sc, jnp.int32),
                                       jnp.asarray(j * sc, jnp.int32))
                dq_i = dq_p if dq_i is None else _acc(dq_i, dq_p)
                # dk/dv come home chunk by chunk: host fp32 accumulation.
                mgr.accumulate(("dk", j), dk_p)
                mgr.accumulate(("dv", j), dv_p)
                mgr.release(("k", j))
                mgr.release(("v", j))
            dqs.append(self._unlay(dq_i))
            for key in (("q", i), ("g", i), ("o", i), ("l", i)):
                mgr.release(key)
        dq = jnp.concatenate(dqs, axis=1).astype(qdt)
        dk = jnp.concatenate(
            [self._unlay(jnp.asarray(mgr.host_array(("dk", j))))
             for j in range(C)], axis=1).astype(kdt)
        dv = jnp.concatenate(
            [self._unlay(jnp.asarray(mgr.host_array(("dv", j))))
             for j in range(C)], axis=1).astype(vdt)
        return dq, dk, dv


def chunked_attention_2d(q, k, v, *, mesh, cfg: Attn2DConfig, chunks: int,
                         doc_start=None, offload=None):
    """Forward + manual-vjp entry point for the chunk pipeline.

    Returns ``(out, vjp_fn)`` with ``vjp_fn(d_out) -> (dq, dk, dv)``; all
    arrays in logical token order.  ``offload`` (an ``OffloadManager``)
    carries the residency budget and telemetry; a fresh unbounded manager
    is used when omitted.
    """
    ca = ChunkedAttention(mesh, cfg, chunks=chunks, offload=offload)
    out = ca.forward(q, k, v, doc_start=doc_start)
    return out, ca.vjp
