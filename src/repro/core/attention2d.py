"""2D-Attention: head-parallel × context-parallel distributed attention.

The paper's core mechanism (LoongTrain §4), TPU-native:

* **SeqAlltoAll** (Ulysses): ``jax.lax.all_to_all`` over the ``head`` mesh
  axis redistributes Q/K/V from ``(S/d_sp sequence, all heads)`` to
  ``(S/d_cp sequence, H/d_hp heads)`` and back.
* **KV replication** (paper §4.2): when ``d_hp > H_kv`` the KV heads are
  replicated *before* the all-to-all; the replica-gradient aggregation of the
  backward pass falls out of JAX's transpose of ``jnp.repeat``.
* **Double-Ring-Attention** (paper §4.3, Algorithm 2): the context group is
  factored into ``outer × inner`` mesh axes.  KV chunks rotate with
  ``jax.lax.ppermute`` — inner ring every micro-step, outer ring once per
  outer step, issued *before* the inner loop so XLA's latency-hiding
  scheduler overlaps it with the whole inner round (the paper's prefetch).
  Two concurrent ppermutes on distinct mesh axes travel on distinct ICI
  torus dimensions — the TPU analogue of "use all NICs".
* **Zigzag causal load balance**: context rank ``i`` owns logical sequence
  chunks ``(i, 2·cp−1−i)`` (the data pipeline pre-permutes tokens, paper
  §4.4's loader post-processing).  Every ring step then computes exactly two
  C×C sub-blocks per rank:

      j < i : whole-Q × K_lo        (both full)
      j = i : causal diagonal       (two causal halves + one full)
      j > i : Q_hi × whole-K        (both full)

  so per-step FLOPs are balanced and ≈ useful FLOPs.
* The ring is one ``jax.custom_vjp`` unit: forward accumulates (out, lse)
  with the flash combine rule; backward re-runs the ring, accumulating dq
  locally while dk/dv ride around the rings *with* their KV chunk and arrive
  home after a full cycle.

Everything here is the *per-shard* program (runs under ``shard_map``);
``attention_2d`` is the global-array entry point.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.topology import (AXIS_HP, AXIS_INNER, AXIS_OUTER, BATCH_AXES,
                                 SEQ_AXES)
from repro.kernels.ops import flash_attention, flash_bwd_chunk, flash_fwd_chunk
from repro.kernels.ref import NEG_INF, combine_pair


class Attn2DConfig(NamedTuple):
    """Static 2D-Attention configuration (hashable)."""
    hp: int = 1
    n_out: int = 1            # outer ring size (d_cp / w)
    w: int = 1                # inner ring size (paper's w)
    causal: bool = True
    zigzag: bool = True       # False: contiguous chunks (hybrid/SSM models)
    window: int | None = None
    softcap: float = 0.0
    scale: float | None = None
    impl: str = "auto"
    axis_hp: str = AXIS_HP
    axis_outer: str = AXIS_OUTER
    axis_inner: str = AXIS_INNER

    @property
    def cp(self) -> int:
        return self.n_out * self.w


class RingConfig(NamedTuple):
    """Static ring configuration (the custom_vjp nondiff arg)."""
    n_out: int
    w: int
    causal: bool
    zigzag: bool
    window: int | None
    softcap: float
    scale: float
    impl: str
    axis_outer: str
    axis_inner: str

    @property
    def cp(self) -> int:
        return self.n_out * self.w


def _shift(x, axis: str, size: int):
    """Ring ppermute: every rank sends to (r+1) % size, receives from r-1."""
    if size == 1:
        return x
    return lax.ppermute(x, axis, [(r, (r + 1) % size) for r in range(size)])


def _ring_indices(cfg: RingConfig):
    i_out = lax.axis_index(cfg.axis_outer)
    i_in = lax.axis_index(cfg.axis_inner)
    return i_out, i_in, i_out * cfg.w + i_in


def _visiting(cfg: RingConfig, i_out, i_in, o: int, t: int):
    """Global cp index of the KV chunk visiting this rank at step (o, t)."""
    j_out = (i_out - o) % cfg.n_out
    j_in = (i_in - t) % cfg.w
    return j_out * cfg.w + j_in


def _kw(cfg: RingConfig):
    return dict(softcap=cfg.softcap, scale=cfg.scale, impl=cfg.impl)


# ---------------------------------------------------------------------------
# Ring forward
# ---------------------------------------------------------------------------

def _step_fwd(q, kc, vc, o: int, t: int, i_out, i_in, i, cfg: RingConfig):
    """Partial (out, lse) of local q against the visiting KV chunk pair."""
    kw = _kw(cfg)
    if not cfg.causal:
        return flash_fwd_chunk(q, kc, vc, causal=False, **kw)

    if not cfg.zigzag:
        # Contiguous chunks (no causal load balance): chunk r = cp rank r.
        # Used by hybrid/SSM models whose recurrent layers need contiguous
        # sequence shards; the paper's balanced layout needs the zigzag
        # data permutation which those layers cannot tolerate.
        if o == 0 and t == 0:
            return flash_fwd_chunk(q, kc, vc, causal=True,
                                   window=cfg.window, **kw)
        j = _visiting(cfg, i_out, i_in, o, t)
        s_loc = q.shape[1]

        def past(q, kc, vc):
            if cfg.window is None:
                return flash_fwd_chunk(q, kc, vc, causal=False, **kw)
            return flash_fwd_chunk(q, kc, vc, causal=True, window=cfg.window,
                                   mask_offset=(i - j) * s_loc, **kw)

        def future(q, kc, vc):
            b, _, hq, dh = q.shape
            return (jnp.zeros_like(q),
                    jnp.full((b, hq, s_loc), NEG_INF, jnp.float32))

        return lax.cond(j < i, past, future, q, kc, vc)

    c = q.shape[1] // 2
    cp = cfg.cp
    if o == 0 and t == 0:
        # Diagonal: q_lo=chunk i, q_hi=chunk 2cp-1-i; kv = same chunks.
        o_lo, l_lo = flash_fwd_chunk(
            q[:, :c], kc[:, :c], vc[:, :c], causal=True, window=cfg.window,
            **kw)
        if cfg.window is None:
            # bottom-right-aligned causal == full on k_lo + diag on k_hi
            o_hi, l_hi = flash_fwd_chunk(q[:, c:], kc, vc, causal=True, **kw)
        else:
            p1 = flash_fwd_chunk(q[:, c:], kc[:, :c], vc[:, :c], causal=True,
                                 window=cfg.window,
                                 mask_offset=(2 * cp - 1 - 2 * i) * c, **kw)
            p2 = flash_fwd_chunk(q[:, c:], kc[:, c:], vc[:, c:], causal=True,
                                 window=cfg.window, **kw)
            o_hi, l_hi = combine_pair(p1[0], p1[1], p2[0], p2[1])
        return (jnp.concatenate([o_lo, o_hi], axis=1),
                jnp.concatenate([l_lo, l_hi], axis=2))

    j = _visiting(cfg, i_out, i_in, o, t)

    if cfg.window is None:
        def case_a(q, kc, vc):
            # j < i: whole local q attends the visitor's low chunk, fully.
            return flash_fwd_chunk(q, kc[:, :c], vc[:, :c], causal=False,
                                   **kw)

        def case_b(q, kc, vc):
            # j > i: only q_hi attends, but against the visitor's whole kv.
            o_hi, l_hi = flash_fwd_chunk(q[:, c:], kc, vc, causal=False,
                                         **kw)
            return (jnp.concatenate([jnp.zeros_like(o_hi), o_hi], axis=1),
                    jnp.concatenate([jnp.full_like(l_hi, NEG_INF), l_hi],
                                    axis=2))
    else:
        def case_a(q, kc, vc):
            lo = flash_fwd_chunk(q[:, :c], kc[:, :c], vc[:, :c], causal=True,
                                 window=cfg.window, mask_offset=(i - j) * c,
                                 **kw)
            hi = flash_fwd_chunk(q[:, c:], kc[:, :c], vc[:, :c], causal=True,
                                 window=cfg.window,
                                 mask_offset=(2 * cp - 1 - i - j) * c, **kw)
            return (jnp.concatenate([lo[0], hi[0]], axis=1),
                    jnp.concatenate([lo[1], hi[1]], axis=2))

        def case_b(q, kc, vc):
            h1 = flash_fwd_chunk(q[:, c:], kc[:, :c], vc[:, :c], causal=True,
                                 window=cfg.window,
                                 mask_offset=(2 * cp - 1 - i - j) * c, **kw)
            h2 = flash_fwd_chunk(q[:, c:], kc[:, c:], vc[:, c:], causal=True,
                                 window=cfg.window, mask_offset=(j - i) * c,
                                 **kw)
            o_hi, l_hi = combine_pair(h1[0], h1[1], h2[0], h2[1])
            return (jnp.concatenate([jnp.zeros_like(o_hi), o_hi], axis=1),
                    jnp.concatenate([jnp.full_like(l_hi, NEG_INF), l_hi],
                                    axis=2))

    return lax.cond(j < i, case_a, case_b, q, kc, vc)


def _ring_fwd(q, k, v, cfg: RingConfig):
    i_out, i_in, i = _ring_indices(cfg)
    acc_o = None
    acc_l = None
    k0, v0 = k, v
    for o in range(cfg.n_out):
        nxt_outer = None
        if o < cfg.n_out - 1:
            # Outer prefetch (Alg. 2 line 3): issued before the inner loop so
            # it overlaps the whole inner round.
            nxt_outer = (_shift(k0, cfg.axis_outer, cfg.n_out),
                         _shift(v0, cfg.axis_outer, cfg.n_out))
        kc, vc = k0, v0
        for t in range(cfg.w):
            nxt_inner = None
            if t < cfg.w - 1:
                nxt_inner = (_shift(kc, cfg.axis_inner, cfg.w),
                             _shift(vc, cfg.axis_inner, cfg.w))
            po, pl_ = _step_fwd(q, kc, vc, o, t, i_out, i_in, i, cfg)
            if acc_o is None:
                acc_o, acc_l = po.astype(jnp.float32), pl_
            else:
                acc_o, acc_l = combine_pair(acc_o, acc_l, po, pl_)
            if nxt_inner is not None:
                kc, vc = nxt_inner
        if nxt_outer is not None:
            k0, v0 = nxt_outer
    return acc_o.astype(q.dtype), acc_l


# ---------------------------------------------------------------------------
# Ring backward
# ---------------------------------------------------------------------------

def _step_bwd(q, kc, vc, out, lse, do, o: int, t: int, i_out, i_in, i,
              cfg: RingConfig):
    """(dq_part, dk_part, dv_part) for the KV chunk visiting at (o, t).

    ``out``/``lse`` are the final combined values (global softmax), so each
    step's contribution is exact and linear.
    """
    kw = _kw(cfg)
    if not cfg.causal:
        return flash_bwd_chunk(q, kc, vc, out, lse, do, causal=False, **kw)

    if not cfg.zigzag:
        if o == 0 and t == 0:
            return flash_bwd_chunk(q, kc, vc, out, lse, do, causal=True,
                                   window=cfg.window, **kw)
        j = _visiting(cfg, i_out, i_in, o, t)
        s_loc = q.shape[1]

        def past(q, kc, vc, out, lse, do):
            if cfg.window is None:
                return flash_bwd_chunk(q, kc, vc, out, lse, do,
                                       causal=False, **kw)
            return flash_bwd_chunk(q, kc, vc, out, lse, do, causal=True,
                                   window=cfg.window,
                                   mask_offset=(i - j) * s_loc, **kw)

        def future(q, kc, vc, out, lse, do):
            return (jnp.zeros_like(q), jnp.zeros_like(kc),
                    jnp.zeros_like(vc))

        return lax.cond(j < i, past, future, q, kc, vc, out, lse, do)

    c = q.shape[1] // 2
    cp = cfg.cp
    q_lo, q_hi = q[:, :c], q[:, c:]
    o_lo, o_hi = out[:, :c], out[:, c:]
    g_lo, g_hi = do[:, :c], do[:, c:]
    l_lo, l_hi = lse[:, :, :c], lse[:, :, c:]
    zeros_kv = jnp.zeros_like(kc[:, :c])

    if o == 0 and t == 0:
        dq1, dk1, dv1 = flash_bwd_chunk(q_lo, kc[:, :c], vc[:, :c], o_lo,
                                        l_lo, g_lo, causal=True,
                                        window=cfg.window, **kw)
        if cfg.window is None:
            dq2, dkf, dvf = flash_bwd_chunk(q_hi, kc, vc, o_hi, l_hi, g_hi,
                                            causal=True, **kw)
        else:
            a1 = flash_bwd_chunk(q_hi, kc[:, :c], vc[:, :c], o_hi, l_hi,
                                 g_hi, causal=True, window=cfg.window,
                                 mask_offset=(2 * cp - 1 - 2 * i) * c, **kw)
            a2 = flash_bwd_chunk(q_hi, kc[:, c:], vc[:, c:], o_hi, l_hi,
                                 g_hi, causal=True, window=cfg.window, **kw)
            dq2 = a1[0] + a2[0]
            dkf = jnp.concatenate([a1[1], a2[1]], axis=1)
            dvf = jnp.concatenate([a1[2], a2[2]], axis=1)
        dq = jnp.concatenate([dq1, dq2], axis=1)
        dk = dkf + jnp.concatenate([dk1, jnp.zeros_like(dk1)], axis=1)
        dv = dvf + jnp.concatenate([dv1, jnp.zeros_like(dv1)], axis=1)
        return dq, dk, dv

    j = _visiting(cfg, i_out, i_in, o, t)

    if cfg.window is None:
        def case_a(q, kc, vc, out, lse, do):
            dqa, dk_lo, dv_lo = flash_bwd_chunk(
                q, kc[:, :c], vc[:, :c], out, lse, do, causal=False, **kw)
            return (dqa,
                    jnp.concatenate([dk_lo, zeros_kv], axis=1),
                    jnp.concatenate([dv_lo, zeros_kv], axis=1))

        def case_b(q, kc, vc, out, lse, do):
            dqb, dka, dva = flash_bwd_chunk(
                q[:, c:], kc, vc, out[:, c:], lse[:, :, c:], do[:, c:],
                causal=False, **kw)
            return (jnp.concatenate([jnp.zeros_like(dqb), dqb], axis=1),
                    dka, dva)
    else:
        def case_a(q, kc, vc, out, lse, do):
            d1 = flash_bwd_chunk(q[:, :c], kc[:, :c], vc[:, :c], out[:, :c],
                                 lse[:, :, :c], do[:, :c], causal=True,
                                 window=cfg.window, mask_offset=(i - j) * c,
                                 **kw)
            d2 = flash_bwd_chunk(q[:, c:], kc[:, :c], vc[:, :c], out[:, c:],
                                 lse[:, :, c:], do[:, c:], causal=True,
                                 window=cfg.window,
                                 mask_offset=(2 * cp - 1 - i - j) * c, **kw)
            return (jnp.concatenate([d1[0], d2[0]], axis=1),
                    jnp.concatenate([d1[1] + d2[1], zeros_kv], axis=1),
                    jnp.concatenate([d1[2] + d2[2], zeros_kv], axis=1))

        def case_b(q, kc, vc, out, lse, do):
            d1 = flash_bwd_chunk(q[:, c:], kc[:, :c], vc[:, :c], out[:, c:],
                                 lse[:, :, c:], do[:, c:], causal=True,
                                 window=cfg.window,
                                 mask_offset=(2 * cp - 1 - i - j) * c, **kw)
            d2 = flash_bwd_chunk(q[:, c:], kc[:, c:], vc[:, c:], out[:, c:],
                                 lse[:, :, c:], do[:, c:], causal=True,
                                 window=cfg.window, mask_offset=(j - i) * c,
                                 **kw)
            return (jnp.concatenate([jnp.zeros_like(d1[0]), d1[0] + d2[0]],
                                    axis=1),
                    jnp.concatenate([d1[1], d2[1]], axis=1),
                    jnp.concatenate([d1[2], d2[2]], axis=1))

    return lax.cond(j < i, case_a, case_b, q, kc, vc, out, lse, do)


def _ring_bwd(q, k, v, out, lse, do, cfg: RingConfig):
    i_out, i_in, i = _ring_indices(cfg)
    dq = jnp.zeros(q.shape, jnp.float32)
    k0, v0 = k, v
    dk0 = jnp.zeros(k.shape, jnp.float32)
    dv0 = jnp.zeros(v.shape, jnp.float32)
    for o in range(cfg.n_out):
        kc, vc, dkc, dvc = k0, v0, dk0, dv0
        for t in range(cfg.w):
            dq_p, dk_p, dv_p = _step_bwd(q, kc, vc, out, lse, do, o, t,
                                         i_out, i_in, i, cfg)
            dq = dq + dq_p.astype(jnp.float32)
            dkc = dkc + dk_p.astype(jnp.float32)
            dvc = dvc + dv_p.astype(jnp.float32)
            # dk/dv ride the inner ring with their chunk; the last rotation
            # completes the inner cycle so the chunk grads are home (within
            # this outer round) before the outer hop.
            last = (t == cfg.w - 1) and (o == cfg.n_out - 1)
            if not last:
                kc = _shift(kc, cfg.axis_inner, cfg.w)
                vc = _shift(vc, cfg.axis_inner, cfg.w)
            dkc = _shift(dkc, cfg.axis_inner, cfg.w)
            dvc = _shift(dvc, cfg.axis_inner, cfg.w)
        # Outer hop: the visiting set (with its accumulated grads) moves on;
        # after n_out hops every chunk's grads are back at their owner.
        if o < cfg.n_out - 1:
            k0 = _shift(kc, cfg.axis_outer, cfg.n_out)
            v0 = _shift(vc, cfg.axis_outer, cfg.n_out)
        dk0 = _shift(dkc, cfg.axis_outer, cfg.n_out)
        dv0 = _shift(dvc, cfg.axis_outer, cfg.n_out)
    return dq.astype(q.dtype), dk0.astype(k.dtype), dv0.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def ring_attention(q, k, v, cfg: RingConfig):
    """Double-ring zigzag attention over the local (post-AlltoAll) shards.

    q: (b, S/cp, Hq/hp, d);  k/v: (b, S/cp, Hkv_eff/hp, d).
    """
    out, _ = _ring_fwd(q, k, v, cfg)
    return out


def _ring_vjp_fwd(q, k, v, cfg: RingConfig):
    out, lse = _ring_fwd(q, k, v, cfg)
    return out, (q, k, v, out, lse)


def _ring_vjp_bwd(cfg: RingConfig, res, do):
    q, k, v, out, lse = res
    return _ring_bwd(q, k, v, out, lse, do, cfg)


ring_attention.defvjp(_ring_vjp_fwd, _ring_vjp_bwd)


# ---------------------------------------------------------------------------
# SeqAlltoAll + public API
# ---------------------------------------------------------------------------

def attention_2d_local(q, k, v, cfg: Attn2DConfig):
    """Per-shard 2D-Attention (call under shard_map).

    q: (b, S/d_sp, Hq, d);  k/v: (b, S/d_sp, Hkv, d).  Returns q-shaped out.
    """
    b, s_loc, hq, dh = q.shape
    hkv = k.shape[2]
    scale = cfg.scale if cfg.scale is not None else 1.0 / (dh ** 0.5)

    if cfg.hp > hkv:
        # Paper §4.2: replicate KV heads to d_hp before the SeqAlltoAll.
        assert cfg.hp % hkv == 0, (cfg.hp, hkv)
        rep = cfg.hp // hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)

    if cfg.hp > 1:
        assert hq % cfg.hp == 0, (hq, cfg.hp)
        q = lax.all_to_all(q, cfg.axis_hp, 2, 1, tiled=True)
        k = lax.all_to_all(k, cfg.axis_hp, 2, 1, tiled=True)
        v = lax.all_to_all(v, cfg.axis_hp, 2, 1, tiled=True)

    if cfg.cp == 1:
        out = flash_attention(q, k, v, causal=cfg.causal, window=cfg.window,
                              softcap=cfg.softcap, scale=scale,
                              impl=cfg.impl)
    else:
        rcfg = RingConfig(n_out=cfg.n_out, w=cfg.w, causal=cfg.causal,
                          zigzag=cfg.zigzag and cfg.causal,
                          window=cfg.window, softcap=cfg.softcap,
                          scale=scale, impl=cfg.impl,
                          axis_outer=cfg.axis_outer,
                          axis_inner=cfg.axis_inner)
        out = ring_attention(q, k, v, rcfg)

    if cfg.hp > 1:
        out = lax.all_to_all(out, cfg.axis_hp, 1, 2, tiled=True)
    return out


def _shard_map(f, mesh, in_specs, out_specs):
    try:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    except TypeError:  # older spelling
        from jax.experimental.shard_map import shard_map as _sm
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)


def attention_2d(q, k, v, *, mesh, cfg: Attn2DConfig):
    """Global-array 2D-Attention: q (B, S, Hq, d), k/v (B, S, Hkv, d).

    B is sharded over the batch axes, S over the sp axes (the zigzag
    data-layout contract — see data/pipeline.py).
    """
    spec = P(BATCH_AXES, SEQ_AXES, None, None)
    f = _shard_map(functools.partial(attention_2d_local, cfg=cfg),
                   mesh, (spec, spec, spec), spec)
    return f(q, k, v)
