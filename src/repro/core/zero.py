"""Hybrid ZeRO (paper §5.1) as sharding rules over the dp × sp mesh.

LoongTrain/AMSP insight: shard optimizer/parameter state not just over DP
but over ``dp × sp``, with a *configurable* sharding extent trading memory
against collective latency (Full-Replica / Partial- / Full-Sharding).

JAX mapping: ZeRO is a *sharding spec* on the param / optimizer pytrees.
XLA then emits exactly the ZeRO collectives: all-gather of params at use
(ZeRO-3), reduce-scatter of grads into the sharded optimizer update
(ZeRO-1/2).  ``zero_shardings`` picks, per leaf, the largest tensor dim
divisible by the sharding-group size; leaves too small to shard stay
replicated (their memory is negligible by construction).

Sharding never crosses the ``pod`` axis by default — cross-pod gathers
would traverse DCN (AMSP's Partial-Sharding; override with
``include_pod=True``).
"""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.topology import (AXIS_DATA, AXIS_HP, AXIS_INNER, AXIS_OUTER,
                                 AXIS_POD)

#: preference-ordered sharding groups (AMSP: full > partial > replica)
_DEFAULT_GROUPS = (
    (AXIS_DATA, AXIS_HP, AXIS_OUTER, AXIS_INNER),   # full dp×sp sharding
    (AXIS_HP, AXIS_OUTER, AXIS_INNER),              # sp-only
    (AXIS_DATA,),                                   # dp-only
)


def _group_size(mesh: Mesh, group) -> int:
    return int(np.prod([mesh.shape[a] for a in group]))


def _spec_for_group(shape, mesh: Mesh, group) -> P | None:
    g = _group_size(mesh, group)
    if g <= 1:
        return None
    # largest dim divisible by the group size wins
    cands = [(d, s) for d, s in enumerate(shape) if s % g == 0 and s >= g]
    if not cands:
        return None
    dim = max(cands, key=lambda t: t[1])[0]
    spec = [None] * len(shape)
    spec[dim] = group
    return P(*spec)


def leaf_spec(shape, mesh: Mesh, groups=_DEFAULT_GROUPS,
              min_elems: int = 2 ** 12) -> P:
    """Pick a PartitionSpec for one param leaf.

    For each candidate group, if no tensor dim divides the full group
    size, fall back to the *largest divisible sub-group* before moving
    on: axes are dropped from the minor end (``inner`` first), so an
    awkward leaf still shards e.g. ``(data, head, outer)``-wide instead
    of silently replicating.
    """
    if np.prod(shape, dtype=np.int64) < min_elems:
        return P()
    for group in groups:
        for end in range(len(group), 0, -1):
            spec = _spec_for_group(shape, mesh, group[:end])
            if spec is not None:
                return spec
    return P()


def leaf_extent(shape, mesh: Mesh, groups=_DEFAULT_GROUPS,
                min_elems: int = 2 ** 12) -> tuple[int, tuple]:
    """(sharding extent, axes) ``leaf_spec`` chose for this leaf — the
    per-leaf ZeRO degree surfaced by ``ExecutionPlan.describe()``."""
    spec = leaf_spec(shape, mesh, groups, min_elems)
    for entry in spec:
        if entry is not None:
            axes = entry if isinstance(entry, tuple) else (entry,)
            return _group_size(mesh, axes), tuple(axes)
    return 1, ()


def zero_shardings(params, mesh: Mesh, *, include_pod: bool = False,
                   zero_axes=None, groups=None):
    """NamedSharding pytree for params (and, reused, optimizer moments).

    ``groups`` (preference-ordered) is normally supplied by
    ``core/plan.py``, which picks the extent from a memory model; the
    default is the legacy most-sharded-first order.
    """
    if groups is None:
        groups = _DEFAULT_GROUPS
        if zero_axes is not None:
            groups = (tuple(zero_axes),) + _DEFAULT_GROUPS
        if include_pod:
            groups = ((AXIS_POD,) + _DEFAULT_GROUPS[0],) + groups
    return jax.tree.map(
        lambda x: NamedSharding(mesh, leaf_spec(x.shape, mesh, groups)),
        params)


def replicated_shardings(params, mesh: Mesh):
    """Full-Replica mode (ZeRO off) — small models / debugging."""
    return jax.tree.map(lambda x: NamedSharding(mesh, P()), params)


def tp_shardings(params, mesh: Mesh):
    """Weight-stationary (tensor-parallel-style) shardings for serving.

    Weights shard 16-way over the model axes only and are *never gathered*:
    with decode's tiny activations, GSPMD moves the (small) activations
    through psum/all-gather instead of moving the (huge) weights — the
    standard inference-TP layout.  Replicated across data (a serving
    replica per data rank)."""
    groups = ((AXIS_HP, AXIS_OUTER, AXIS_INNER),)
    return jax.tree.map(
        lambda x: NamedSharding(mesh, leaf_spec(x.shape, mesh, groups)),
        params)
