"""Host KV offload: chunk residency management for sequence-chunk
pipelined attention (FPDT, arxiv 2408.16978; the ROADMAP's long-context
item).

The double-ring path keeps the whole local sequence in HBM, so max
trainable context is capped by device memory.  Chunk pipelining streams
the sequence through attention one chunk at a time; everything not in
flight lives in (pinned) host memory.  ``OffloadManager`` is the broker:

* ``put(key, arr)``    — device → host snapshot (D2H); the device copy is
  dropped from the residency account.
* ``prefetch(key)``    — start the host → device copy (``jax.device_put``
  dispatches asynchronously, so a prefetch issued one chunk ahead
  overlaps the copy against the current chunk's ring steps).
* ``get(key)``         — the device array, *after* the H2D copy has
  landed: an in-flight copy is waited on (``block_until_ready``) before
  any byte is readable, so a consumer can never observe a torn chunk.
  A ``get`` without a prior ``prefetch`` still works but counts a
  ``stall`` — the pipeline-quality signal the property tests and the
  offload bench track.
* ``release(key)``     — drop the device copy; the host bits are already
  current (no D2H traffic for read-only chunks like K/V).
* ``accumulate(key, delta)`` — host-side fp32 ``+=`` for gradients that
  come home chunk by chunk (dk/dv in the backward pipeline).

Residency accounting is the contract: ``device_bytes`` tracks every
manager-held device chunk, ``peak_device_bytes`` the high-water mark, and
a configured ``budget_bytes`` is *enforced* — a fetch that would exceed
it raises :class:`BudgetExceeded` instead of silently oversubscribing
HBM.  ``tests/test_offload.py`` drives random schedules against these
invariants (never read before landing, never exceed the budget, evict/
prefetch round-trips are bitwise identity).

Pure host/device bookkeeping: no repro imports, so ``core`` modules may
depend on it freely.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Hashable

import numpy as np

#: chunk residency states
HOST, FETCHING, DEVICE = "host", "fetching", "device"


class BudgetExceeded(RuntimeError):
    """A fetch would push manager-held device bytes over the budget."""


@dataclasses.dataclass
class _Entry:
    host: np.ndarray | None = None
    dev: Any = None
    state: str = HOST
    landed: bool = False          # H2D copy known complete
    nbytes: int = 0


class OffloadManager:
    """Host↔device chunk broker with enforced residency accounting.

    ``budget_bytes=None`` disables enforcement (accounting still runs).
    """

    def __init__(self, budget_bytes: int | None = None):
        self.budget_bytes = budget_bytes
        self._entries: dict[Hashable, _Entry] = {}
        # accounting / telemetry
        self.device_bytes = 0
        self.peak_device_bytes = 0
        self.host_bytes = 0
        self.h2d_bytes = 0
        self.d2h_bytes = 0
        self.stalls = 0     # get() before any prefetch (sync fetch)
        self.waits = 0      # get() blocked on an in-flight copy

    # -- internal ----------------------------------------------------------

    def _charge(self, key, n: int):
        if self.budget_bytes is not None \
                and self.device_bytes + n > self.budget_bytes:
            raise BudgetExceeded(
                f"fetching {key!r} ({n}B) would put device residency at "
                f"{self.device_bytes + n}B > budget {self.budget_bytes}B")
        self.device_bytes += n
        self.peak_device_bytes = max(self.peak_device_bytes,
                                     self.device_bytes)

    def _entry(self, key) -> _Entry:
        e = self._entries.get(key)
        assert e is not None, f"unknown offload chunk {key!r}"
        return e

    # -- public ------------------------------------------------------------

    def put(self, key, arr) -> None:
        """Stage ``arr`` on the host (D2H copy); drops any device copy."""
        host = np.asarray(arr)
        old = self._entries.get(key)
        if old is not None:
            if old.state != HOST:
                self.device_bytes -= old.nbytes
            if old.host is not None:
                self.host_bytes -= old.host.nbytes
        self._entries[key] = _Entry(host=host, state=HOST,
                                    nbytes=host.nbytes)
        self.host_bytes += host.nbytes
        self.d2h_bytes += host.nbytes

    def accumulate(self, key, delta) -> None:
        """Host-side fp32 ``+=`` (first call initializes from ``delta``)."""
        d = np.asarray(delta, np.float32)
        e = self._entries.get(key)
        self.d2h_bytes += d.nbytes
        if e is None or e.host is None:
            self._entries[key] = _Entry(host=d.copy(), state=HOST,
                                        nbytes=d.nbytes)
            self.host_bytes += d.nbytes
        else:
            assert e.state == HOST, f"accumulate into resident {key!r}"
            e.host = e.host + d

    def prefetch(self, key) -> None:
        """Start the async H2D copy; no-op if already in flight/resident."""
        e = self._entry(key)
        if e.state != HOST:
            return
        assert e.host is not None, f"{key!r} has no host copy to fetch"
        self._charge(key, e.nbytes)
        import jax
        e.dev = jax.device_put(e.host)       # dispatches asynchronously
        e.state, e.landed = FETCHING, False
        self.h2d_bytes += e.nbytes

    def get(self, key):
        """The device array for ``key`` — never before its copy landed."""
        e = self._entry(key)
        if e.state == HOST:
            self.stalls += 1                 # pipeline bubble: sync fetch
            self.prefetch(key)
        if e.state == FETCHING:
            self.waits += 1
            import jax
            jax.block_until_ready(e.dev)     # the landing barrier
            e.state, e.landed = DEVICE, True
        assert e.state == DEVICE and e.landed, (key, e.state)
        return e.dev

    def release(self, key) -> None:
        """Drop the device copy; host bits stay current (no D2H)."""
        e = self._entry(key)
        if e.state == HOST:
            return
        if e.state == FETCHING:
            import jax
            jax.block_until_ready(e.dev)     # cannot free mid-copy
        e.dev, e.state, e.landed = None, HOST, False
        self.device_bytes -= e.nbytes

    def host_array(self, key) -> np.ndarray:
        """The host copy (for final gather of accumulated grads)."""
        e = self._entry(key)
        assert e.host is not None, key
        return e.host

    def discard(self, key) -> None:
        """Forget ``key`` entirely, returning its bytes to the accounts."""
        e = self._entries.pop(key, None)
        if e is None:
            return
        if e.state != HOST:
            self.device_bytes -= e.nbytes
        if e.host is not None:
            self.host_bytes -= e.host.nbytes

    def keys(self):
        return self._entries.keys()

    def resident(self):
        """Keys currently holding device bytes (fetching or landed)."""
        return [k for k, e in self._entries.items() if e.state != HOST]

    def stats(self) -> dict:
        return {"device_bytes": self.device_bytes,
                "peak_device_bytes": self.peak_device_bytes,
                "host_bytes": self.host_bytes,
                "h2d_bytes": self.h2d_bytes, "d2h_bytes": self.d2h_bytes,
                "stalls": self.stalls, "waits": self.waits}


def prefetched(mgr: OffloadManager, keys, *, depth: int = 2,
               release: bool = True):
    """Iterate ``(key, device_array)`` with a ``depth``-deep prefetch
    window — the double-buffer schedule (depth=2: active + next) that the
    pipelined loops use.  With enough budget for ``depth`` chunks this
    schedule incurs zero stalls (a property the tests pin)."""
    keys = list(keys)
    for k in keys[:depth]:
        mgr.prefetch(k)
    for n, k in enumerate(keys):
        arr = mgr.get(k)
        if n + depth < len(keys):
            mgr.prefetch(keys[n + depth])
        yield k, arr
        if release:
            mgr.release(k)
