"""Plan-aware sharded checkpointing: async atomic saves, elastic restore.

``CheckpointManager`` is the one surface the trainer (and examples) talk
to.  Layout (format 2):  <dir>/step_<N>/

    manifest.json       — step, the saving plan, per-leaf sharding layout
    leaf_<i>.s<j>.npy   — shard j of leaf i, split along the leaf's
                          ZeRO-sharded dim

* **Sharded**: each leaf is split along the dim its ``NamedSharding``
  shards (the ExecutionPlan's hybrid-ZeRO layout), so on a fleet every
  host serializes only its addressable shards — bytes-per-host scale
  down with the ZeRO extent instead of every host dumping the full tree.
  The manifest records ``bytes_per_host`` (one shard per leaf) and the
  saving plan, so a restore knows what layout it is reading.
* **Atomic**: written to a unique ``step_<N>.tmp-<pid>-<n>`` dir then
  os.rename'd — a crash never leaves a half-checkpoint visible.
* **Async**: ``save_async`` snapshots device→host synchronously — the
  only part that must block training — and writes in a background
  writer thread.  The manager serializes writers (a second save joins
  the in-flight one) and ``flush`` is atexit-registered, so rapid-fire
  saves and interpreter teardown never race on a tmp dir.
* **Elastic**: ``restore`` reassembles shards and ``jax.device_put``s
  through the *target* plan's shardings — restoring a dp8×cp4 run on
  dp4×cp4 is a reshard at load time, not a migration.

The free functions (``save``/``restore``/``list_steps``/``latest_step``)
remain as the manager's building blocks; ``AsyncCheckpointer`` is the
deprecated pre-manager name, kept as an alias.
"""
from __future__ import annotations

import atexit
import itertools
import json
import os
import shutil
import threading

import jax
import numpy as np

#: manifest format: 1 = whole-leaf files (seed), 2 = per-shard files
FORMAT = 2

_TMP_IDS = itertools.count()


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = [jax.tree_util.keystr(k) for k, _ in flat]
    leaves = [v for _, v in flat]
    return paths, leaves, treedef


def _shard_layout(shape, sharding) -> tuple[int | None, int]:
    """(dim, n_shards) the save layout splits this leaf on.

    Derived from the leaf's ``NamedSharding``: the first sharded dim
    whose mesh-axes extent divides it.  ``(None, 1)`` for replicated,
    unsharded, or plain-numpy leaves (they save whole).
    """
    spec = getattr(sharding, "spec", None)
    mesh = getattr(sharding, "mesh", None)
    if spec is None or mesh is None:
        return None, 1
    for d, entry in enumerate(spec):
        if entry is None or d >= len(shape):
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = int(np.prod([mesh.shape[a] for a in axes]))
        if n > 1 and shape[d] % n == 0:
            return d, n
    return None, 1


def _write_checkpoint(directory: str, step: int, paths, host_leaves,
                      layouts, plan_info: dict | None) -> str:
    """Write one checkpoint dir atomically; returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = f"{final}.tmp-{os.getpid()}-{next(_TMP_IDS)}"
    os.makedirs(tmp)
    try:
        manifest = {"format": FORMAT, "step": step, "leaves": []}
        if plan_info:
            manifest["plan"] = plan_info
        bytes_host = 0
        for i, (p, x, (dim, n)) in enumerate(
                zip(paths, host_leaves, layouts)):
            x = np.asarray(x)
            shards = np.split(x, n, axis=dim) if n > 1 else [x]
            for j, s in enumerate(shards):
                np.save(os.path.join(tmp, f"leaf_{i}.s{j}.npy"), s)
            bytes_host += x.nbytes // n
            manifest["leaves"].append(
                {"path": p, "shape": list(x.shape), "dtype": str(x.dtype),
                 "dim": dim if n > 1 else None, "shards": n})
        manifest["bytes_per_host"] = bytes_host
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)   # never leave a tmp dir
        raise
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def save(tree, step: int, directory: str, plan=None):
    """Blocking atomic sharded save.  Returns the final checkpoint path.

    The shard layout comes from each leaf's own ``.sharding`` (device
    trees) — host-numpy trees save whole.  ``plan`` is recorded in the
    manifest when given.
    """
    paths, leaves, _ = _flatten_with_paths(tree)
    layouts = [_shard_layout(np.shape(x), getattr(x, "sharding", None))
               for x in leaves]
    host_leaves = jax.device_get(leaves)
    return _write_checkpoint(directory, step, paths, host_leaves, layouts,
                             _plan_info(plan))


def _plan_info(plan) -> dict | None:
    """The manifest's record of the saving plan (None when unplanned)."""
    if plan is None:
        return None
    pc = plan.pc
    return {"dp": pc.dp, "hp": pc.hp, "cp_outer": pc.cp_outer,
            "cp_inner": pc.cp_inner, "pods": pc.pods,
            "placement": pc.placement, "zero_mode": plan.zero_mode,
            "zero_extent": plan.mem.get("zero_extent", 1)}


class CheckpointManager:
    """Plan-aware checkpoint manager: the trainer's save/restore surface.

    ``save_async(state, step)`` snapshots device→host at the step
    boundary (the only blocking part) and writes per-shard files in a
    background writer thread; ``restore(state)`` reads any step back and
    reshards it through the *target* plan's shardings.  One writer is in
    flight at a time — overlapping saves join the previous write, and
    ``flush`` (atexit-registered) joins on exit.
    """

    def __init__(self, directory: str, plan=None, keep: int = 3):
        self.directory = directory
        self.plan = plan
        self.keep = keep
        self._writer: threading.Thread | None = None
        atexit.register(self.flush)

    # -- saving -------------------------------------------------------------

    def _snapshot(self, state):
        """Device→host snapshot + the per-leaf shard layout, read from
        the live arrays' shardings (falls back to whole-leaf for host
        trees)."""
        paths, leaves, _ = _flatten_with_paths(state)
        layouts = [_shard_layout(np.shape(x), getattr(x, "sharding", None))
                   for x in leaves]
        host_leaves = jax.device_get(leaves)       # blocking snapshot
        return paths, host_leaves, layouts

    def save(self, state, step: int) -> str:
        """Blocking sharded save (snapshot + write); returns the path."""
        self.flush()
        paths, host, layouts = self._snapshot(state)
        final = _write_checkpoint(self.directory, step, paths, host,
                                  layouts, _plan_info(self.plan))
        self._gc()
        return final

    def save_async(self, state, step: int):
        """Snapshot now, write in the background.

        Joins any write still in flight first, so two saves never race
        on the directory; the writer thread is non-daemon and ``flush``
        is atexit-registered, so teardown mid-write cannot truncate a
        checkpoint.
        """
        self.flush()
        paths, host, layouts = self._snapshot(state)
        info = _plan_info(self.plan)

        def _write():
            _write_checkpoint(self.directory, step, paths, host, layouts,
                              info)
            self._gc()

        self._writer = threading.Thread(target=_write,
                                        name=f"ckpt-write-{step}")
        self._writer.start()

    def flush(self):
        """Join the in-flight write; no-op when idle."""
        w, self._writer = self._writer, None
        if w is not None:
            w.join()

    #: pre-manager name for ``flush`` (AsyncCheckpointer API)
    wait = flush

    def _gc(self):
        steps = self.list_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restoring ----------------------------------------------------------

    def restore(self, template, *, step: int | None = None, plan=None,
                shardings=None):
        """Restore into ``template``'s structure, resharding through the
        target plan (``plan`` overrides the manager's; an explicit
        ``shardings`` pytree overrides both).  Returns ``(state, step)``.
        """
        self.flush()                   # a just-queued save is readable
        plan = plan or self.plan
        if shardings is None and plan is not None:
            shardings = plan.state_shardings(template)
        return restore(template, self.directory, step=step,
                       shardings=shardings)

    def list_steps(self):
        return list_steps(self.directory)

    def latest_step(self):
        return latest_step(self.directory)

    def manifest(self, step: int | None = None) -> dict:
        return read_manifest(self.directory, step)


class AsyncCheckpointer(CheckpointManager):
    """Deprecated pre-manager name; prefer ``CheckpointManager``."""


def list_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and ".tmp" not in name:
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(directory: str):
    steps = list_steps(directory)
    return steps[-1] if steps else None


def read_manifest(directory: str, step: int | None = None) -> dict:
    """The manifest of one checkpoint (latest when ``step`` is None)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}", "manifest.json")
    with open(path) as f:
        return json.load(f)


def _load_leaf(path: str, i: int, entry: dict, fmt: int) -> np.ndarray:
    if fmt < 2:                        # seed layout: one file per leaf
        return np.load(os.path.join(path, f"leaf_{i}.npy"))
    n = entry.get("shards", 1)
    parts = [np.load(os.path.join(path, f"leaf_{i}.s{j}.npy"))
             for j in range(n)]
    return parts[0] if n == 1 else np.concatenate(parts,
                                                  axis=entry["dim"])


def restore(template, directory: str, *, step: int | None = None,
            shardings=None):
    """Restore into ``template``'s structure.  Returns ``(tree, step)``.

    ``shardings``: optional pytree of NamedSharding — pass the *current*
    run's shardings to reshard elastically onto a different mesh; the
    shards are reassembled on host first, so the saved extent and the
    target extent are free to differ.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    fmt = manifest.get("format", 1)
    paths, leaves, treedef = _flatten_with_paths(template)
    by_path = {e["path"]: i for i, e in enumerate(manifest["leaves"])}
    loaded = []
    for p, tmpl in zip(paths, leaves):
        i = by_path[p]
        x = _load_leaf(path, i, manifest["leaves"][i], fmt)
        assert list(x.shape) == list(tmpl.shape), (p, x.shape, tmpl.shape)
        loaded.append(x)
    tree = jax.tree_util.tree_unflatten(treedef, loaded)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree, step
