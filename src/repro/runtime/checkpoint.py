"""Sharded, atomic, async checkpointing with elastic restore.

Layout:  <dir>/step_<N>/
             manifest.json        — tree structure, shapes, dtypes, step
             leaf_<i>.npy         — one file per pytree leaf

* **Atomic**: written to ``step_<N>.tmp`` then os.rename'd — a crash never
  leaves a half-checkpoint visible.
* **Async**: ``save_async`` snapshots to host (device_get) synchronously —
  the only part that must block training — and writes in a daemon thread.
* **Elastic**: ``restore`` takes target shardings; ``jax.device_put`` with a
  *different* mesh/sharding than the one the checkpoint was saved under is
  exactly a reshard — scaling from N to M chips between runs is a restore.
* On multi-host fleets each host would write its addressable shards; the
  manifest format already records per-leaf metadata to extend to that.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

_SEP = "\x1e"


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = [jax.tree_util.keystr(k) for k, _ in flat]
    leaves = [v for _, v in flat]
    return paths, leaves, treedef


def save(tree, step: int, directory: str):
    """Blocking atomic save.  Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    paths, leaves, _ = _flatten_with_paths(tree)
    host_leaves = jax.device_get(leaves)
    manifest = {"step": step, "leaves": []}
    for i, (p, x) in enumerate(zip(paths, host_leaves)):
        x = np.asarray(x)
        np.save(os.path.join(tmp, f"leaf_{i}.npy"), x)
        manifest["leaves"].append(
            {"path": p, "shape": list(x.shape), "dtype": str(x.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Snapshot synchronously, write asynchronously; one write in flight."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, tree, step: int):
        self.wait()
        paths, leaves, treedef = _flatten_with_paths(tree)
        host_leaves = jax.device_get(leaves)     # blocking snapshot
        snapshot = jax.tree_util.tree_unflatten(treedef, host_leaves)

        def _write():
            save(snapshot, step, self.directory)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(list_steps(self.directory))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)


def list_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(directory: str):
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore(template, directory: str, *, step: int | None = None,
            shardings=None):
    """Restore into ``template``'s structure.

    ``shardings``: optional pytree of NamedSharding — pass the *current*
    run's shardings to reshard elastically onto a different mesh.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    paths, leaves, treedef = _flatten_with_paths(template)
    by_path = {e["path"]: i for i, e in enumerate(manifest["leaves"])}
    loaded = []
    for p, tmpl in zip(paths, leaves):
        i = by_path[p]
        x = np.load(os.path.join(path, f"leaf_{i}.npy"))
        assert list(x.shape) == list(tmpl.shape), (p, x.shape, tmpl.shape)
        loaded.append(x)
    tree = jax.tree_util.tree_unflatten(treedef, loaded)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree, step
