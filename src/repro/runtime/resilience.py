"""Fleet resilience: straggler detection, preemption handling, elastic plan.

At thousands of nodes the dominant failure modes are (a) hard node loss,
(b) slow nodes (thermal throttling, ECC retries, flaky ICI links), and
(c) planned preemption.  The JAX/SPMD answer:

* hard loss      -> checkpoint/restart (runtime/checkpoint.py) with
                    deterministic data skip (data/pipeline.py) — training is
                    bitwise-resumable from (checkpoint, step index);
* stragglers     -> there is no per-rank work-stealing inside one SPMD step;
                    detection + replacement is the lever.  ``StepMonitor``
                    keeps a robust per-step-time EWMA and flags outliers so
                    the fleet controller can drain/swap the slow host and
                    resume from the last checkpoint;
* preemption     -> ``PreemptionGuard`` traps SIGTERM, the trainer flushes a
                    final checkpoint at the next step boundary;
* elastic rescale-> checkpoints are mesh-independent (host numpy + target
                    shardings on restore), so N->M chips is restore-time
                    resharding; ``elastic_plan`` picks a valid
                    ParallelConfig for a new chip count.
"""
from __future__ import annotations

import dataclasses
import signal
import time

import numpy as np

from repro.core.topology import ParallelConfig


class StepMonitor:
    """Robust step-time tracker with straggler/outlier flagging."""

    def __init__(self, window: int = 50, threshold: float = 1.5):
        self.window = window
        self.threshold = threshold
        self.times: list[float] = []
        self.flagged: list[tuple[int, float, float]] = []
        self._t0 = None
        self._step = 0

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self):
        assert self._t0 is not None
        dt = time.perf_counter() - self._t0
        self._t0 = None
        self._step += 1
        self.record(self._step, dt)
        return dt

    def lap(self, n: int = 1):
        """Record ``n`` steps at their amortized wall time since the last
        ``start``/``lap``.  A sync-free async-dispatch loop can only
        observe real step time at its sync boundaries, so it calls this
        after each sync with the number of steps dispatched since the
        previous one; straggler flagging then works at sync-window
        granularity."""
        assert self._t0 is not None
        now = time.perf_counter()
        dt = (now - self._t0) / max(n, 1)
        self._t0 = now
        for _ in range(n):
            self._step += 1
            self.record(self._step, dt)
        return dt

    def record(self, step: int, dt: float):
        hist = self.times[-self.window:]
        if len(hist) >= 8:
            med = float(np.median(hist))
            if dt > self.threshold * med:
                self.flagged.append((step, dt, med))
        self.times.append(dt)

    @property
    def median(self) -> float:
        return float(np.median(self.times[-self.window:])) if self.times \
            else 0.0

    def report(self) -> dict:
        return {"steps": len(self.times), "median_s": self.median,
                "stragglers": list(self.flagged)}


class PreemptionGuard:
    """SIGTERM/SIGINT-triggered graceful-shutdown flag.

    ``install`` stashes the handlers it displaces and ``uninstall``
    puts them back, so a guard can be scoped (tests, nested trainers)
    without clobbering the process's signal setup for good.
    """

    def __init__(self, signals=(signal.SIGTERM,)):
        self.requested = False
        self._installed = False
        self._signals = signals
        self._previous: dict = {}

    def install(self):
        if self._installed:
            return
        for s in self._signals:
            try:
                self._previous[s] = signal.signal(s, self._handler)
            except ValueError:
                pass  # not in main thread (tests)
        self._installed = True

    def uninstall(self):
        """Restore the handlers ``install`` displaced."""
        if not self._installed:
            return
        for s, prev in self._previous.items():
            try:
                signal.signal(s, prev)
            except ValueError:
                pass
        self._previous = {}
        self._installed = False

    def _handler(self, signum, frame):
        self.requested = True


def elastic_plan(n_chips: int, *, kv_heads: int, n_heads: int,
                 placement: str = "head_first") -> ParallelConfig:
    """Pick a ParallelConfig for an arbitrary healthy-chip count.

    Keeps the model (sp) extent at 16 where possible (so restored shardings
    stay compatible) and soaks chip-count changes into dp — the standard
    elastic move: lose a node, shrink dp, keep per-chip memory identical.
    """
    sp = 16
    while sp > 1 and (n_chips % sp or n_heads % min(sp, 8)):
        sp //= 2
    dp = max(n_chips // sp, 1)
    hp = min(kv_heads, sp, 8)
    while sp % hp or n_heads % hp:
        hp //= 2
    hp = max(hp, 1)
    cp = sp // hp
    inner = min(cp, 4)
    return ParallelConfig(dp=dp, hp=hp, cp_outer=cp // inner, cp_inner=inner,
                          placement=placement)
