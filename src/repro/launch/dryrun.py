import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is the scale proof without hardware: 512 placeholder host devices
stand in for two 256-chip pods; ``jax.jit(...).lower(*ShapeDtypeStructs)``
+ ``.compile()`` must succeed for every cell, and the compiled artifact
yields the roofline inputs (cost_analysis FLOPs/bytes, memory_analysis,
and collective bytes parsed from the partitioned HLO).

Usage:
    python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
    python -m repro.launch.dryrun --arch all --shape all --mesh both
    ... [--hp 8 --cp 2 --inner 1 --placement context_first]

One cell per process is recommended for the full sweep (see
scripts in EXPERIMENTS.md §Dry-run) — device count is locked at first jax
use, and cells are independent compiles.
"""
import argparse           # noqa: E402
import functools          # noqa: E402
import json               # noqa: E402
import time               # noqa: E402

import jax                # noqa: E402
import jax.numpy as jnp   # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.analysis.hlo import parse_collective_bytes       # noqa: E402
from repro.analysis.roofline import (count_params,          # noqa: E402
                                     model_flops)
from repro.configs import get_config, get_parallel, all_arch_names  # noqa
from repro.configs.common import SHAPES, applicable_shapes  # noqa: E402
from repro.core.plan import ExecutionPlan                   # noqa: E402
from repro.core.topology import ParallelConfig              # noqa: E402
from repro.launch import args as launch_args                # noqa: E402
from repro.launch.mesh import production_plan               # noqa: E402
from repro.models.decode import (cache_shardings,           # noqa: E402
                                 decode_step, init_caches, prefill)
from repro.models.model import init_params, ModelConfig     # noqa: E402
from repro.train.optimizer import init_opt_state            # noqa: E402
from repro.train.train_step import make_train_step          # noqa: E402


def input_specs(plan: ExecutionPlan, shape_name: str):
    """ShapeDtypeStruct stand-ins + the plan's NamedShardings for every
    step input.

    Weak-type-correct, shardable, no device allocation (the shannon/kernels
    pattern).  Returns (structs, shardings) dictionaries keyed like the
    step function's batch argument.
    """
    cfg, shape = plan.cfg, SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    shards = plan.batch_shardings(shape.kind)
    structs = {}

    if shape.kind == "train":
        for k in ("tokens", "labels", "positions"):
            structs[k] = jax.ShapeDtypeStruct((b, s), i32)
    elif shape.kind == "prefill":
        structs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
    else:  # decode
        structs["tokens"] = jax.ShapeDtypeStruct((b, 1), i32)
    if shape.kind != "decode" and cfg.family == "encdec":
        structs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_frames, cfg.d_model), cfg.compute_dtype)
    return structs, {k: shards[k] for k in structs}


def _mem_summary(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception as e:                              # pragma: no cover
        return {"error": str(e)}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _cost_summary(compiled):
    try:
        ca = compiled.cost_analysis()
    except Exception as e:                              # pragma: no cover
        return {"error": str(e), "flops": 0.0}
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0))}


def _layer_group_period(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.attn_every
    return cfg.period


def _with_groups(cfg: ModelConfig, groups: int) -> ModelConfig:
    import dataclasses
    period = _layer_group_period(cfg)
    kw = {"num_layers": groups * period}
    if cfg.family == "encdec":
        kw["encoder_layers"] = groups
        kw["num_layers"] = groups
    return dataclasses.replace(cfg, **kw)


def _compile_cell(plan, shape, *, donate=True, param_sharding="zero"):
    """lower+compile one variant; returns (compiled, t_lower, t_compile)."""
    cfg, rt, mesh = plan.cfg, plan.rt, plan.mesh
    structs, shards = input_specs(plan, shape.name)
    key = jax.random.PRNGKey(0)
    p_struct = jax.eval_shape(lambda: init_params(cfg, key))
    p_sh = plan.serve_shardings(p_struct) if param_sharding == "tp" \
        else plan.param_shardings(p_struct)
    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            o_struct = jax.eval_shape(init_opt_state, p_struct)
            o_sh = plan.opt_shardings(p_sh)
            fn = make_train_step(plan)
            jitted = jax.jit(fn, in_shardings=(p_sh, o_sh, shards),
                             out_shardings=(p_sh, o_sh, None),
                             donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(p_struct, o_struct, structs)
        elif shape.kind == "prefill":
            fn = lambda p, b: prefill(p, b, rt, cfg)   # noqa: E731
            jitted = jax.jit(fn, in_shardings=(p_sh, shards))
            lowered = jitted.lower(p_struct, structs)
        else:
            c_struct = jax.eval_shape(functools.partial(
                init_caches, cfg, shape.global_batch, shape.seq_len))
            c_sh = cache_shardings(cfg, c_struct, mesh, rt.batch_axes)
            pos_struct = jax.ShapeDtypeStruct((), jnp.int32)
            fn = lambda p, c, t, pos: decode_step(    # noqa: E731
                p, c, t, pos, rt, cfg)
            jitted = jax.jit(
                fn,
                in_shardings=(p_sh, c_sh, shards["tokens"],
                              NamedSharding(mesh, P())),
                donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(p_struct, c_struct, structs["tokens"],
                                   pos_struct)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    return compiled, t_lower, t_compile


def _extrapolate(v1: float, v2: float, g_full: float) -> float:
    """Affine in group count: v(g) = a + b·g fitted at g=1,2.

    The slope is clamped at >= 0: compiler noise between the two variants
    (different fusion/CSE choices) must not extrapolate negative.
    """
    b = max(v2 - v1, 0.0)
    a = v1 - b
    return max(a + b * g_full, v1)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             pc: ParallelConfig | None = None, impl: str = "ref",
             remat: str | None = None, out_dir: str | None = None,
             hlo_out: str | None = None, tag_extra: str = "",
             param_sharding: str = "zero",
             plan_only: bool = False, tune_table: bool = False) -> dict:
    """One dry-run cell.

    The full-size model compiles with scanned layers (the scale/memory
    proof).  XLA cost analysis counts a while body once, so FLOPs and
    collective bytes are measured on *unrolled* 1-group and 2-group
    variants and extrapolated affinely in depth — exact for homogeneous
    stacks (zamba2's 3 tail layers ≈ +0.5 group, <1% error).
    """
    import dataclasses
    shape = SHAPES[shape_name]
    if pc is None:
        pc = get_parallel(arch, shape_name, multi_pod)
    plan = production_plan(get_config(arch), pc, multi_pod=multi_pod,
                           impl=impl, remat=remat,
                           seq_len=shape.seq_len,
                           global_batch=shape.global_batch)
    cfg, mesh = plan.cfg, plan.mesh
    chips = mesh.size
    if plan_only:
        desc = plan.describe()
        print(desc)
        rec = {"arch": arch, "shape": shape_name, "plan_only": True,
               "describe": desc}
        if tune_table:
            # PlanTuner's top-5 for this cell's frame (dp pinned to the
            # production layout; the model-axis split, placement and the
            # execution knobs are up for grabs) — the placement
            # trade-offs, inspectable without compiling anything.
            from repro.tune import tune
            result = tune(cfg, num_devices=mesh.size,
                          seq_len=shape.seq_len,
                          global_batch=shape.global_batch,
                          pods=pc.pods, dp=pc.dp,
                          memory_budget_gb=16.0, arch=arch)
            table = result.table(top=5)
            print(table)
            rec["tune_table"] = table
            if result.ranked:
                rec["tuned"] = result.tuned_plan().to_json()
        return rec

    # 1) full-size scanned compile — the dry-run pass/fail + memory truth
    compiled, t_lower, t_compile = _compile_cell(
        plan, shape, param_sharding=param_sharding)
    mem = _mem_summary(compiled)
    hlo = compiled.as_text()
    if hlo_out:
        with open(hlo_out, "w") as f:
            f.write(hlo)

    # 2) unrolled 1-group / 2-group compiles — per-layer cost slopes
    period = _layer_group_period(cfg)
    g_full = cfg.num_layers / period if cfg.family != "encdec"         else cfg.num_layers
    cost, coll = {}, {}
    for g in (1, 2):
        cfg_g = dataclasses.replace(_with_groups(cfg, g),
                                    unroll_loops=True)
        comp_g, _, _ = _compile_cell(dataclasses.replace(plan, cfg=cfg_g),
                                     shape, donate=False,
                                     param_sharding=param_sharding)
        cost[g] = _cost_summary(comp_g)
        coll[g] = parse_collective_bytes(comp_g.as_text())
    cost_x = {k: _extrapolate(cost[1].get(k, 0.0), cost[2].get(k, 0.0),
                              g_full)
              for k in ("flops", "bytes_accessed", "transcendentals")}
    coll_total = _extrapolate(coll[1]["total"], coll[2]["total"], g_full)
    wire_total = _extrapolate(coll[1].get("wire_total", 0),
                              coll[2].get("wire_total", 0), g_full)
    coll_by_op = {k: int(_extrapolate(coll[1]["by_op"].get(k, 0),
                                      coll[2]["by_op"].get(k, 0), g_full))
                  for k in set(coll[1]["by_op"]) | set(coll[2]["by_op"])}
    wire_by_op = {k: int(_extrapolate(coll[1].get("wire_by_op", {}).get(k, 0),
                                      coll[2].get("wire_by_op", {}).get(k, 0),
                                      g_full))
                  for k in set(coll[1].get("wire_by_op", {}))
                  | set(coll[2].get("wire_by_op", {}))}

    n_total, n_active = count_params(cfg)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2pod" if multi_pod else "1pod", "chips": chips,
        "pc": f"hp{pc.hp}/cp{pc.cp_outer}x{pc.cp_inner}/"
              f"{'hf' if pc.placement == 'head_first' else 'cf'}",
        "kind": shape.kind, "impl": impl, "remat": cfg.remat,
        "param_sharding": param_sharding,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "cost": cost_x,
        "cost_raw": {"g1": cost[1], "g2": cost[2], "g_full": g_full},
        "memory": mem,
        "collectives": {"total": int(coll_total),
                        "wire_total": int(wire_total),
                        "by_op": coll_by_op, "wire_by_op": wire_by_op,
                        "counts_g1": coll[1]["counts"],
                        "raw": {"g1": coll[1], "g2": coll[2]}},
        "n_params": n_total, "n_active": n_active,
        "model_flops": model_flops(cfg, shape.kind, shape.seq_len,
                                   shape.global_batch, n_active),
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = (f"{arch}_{shape_name}_{rec['mesh']}_"
               f"{rec['pc'].replace('/', '-')}{tag_extra}")
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    launch_args.add_arch(ap, arch_help="architecture id or 'all'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="1pod",
                    choices=["1pod", "2pod", "both"])
    ap.add_argument("--hp", type=int)
    ap.add_argument("--cp-outer", type=int)
    ap.add_argument("--inner", type=int)
    ap.add_argument("--placement", choices=["head_first", "context_first"])
    ap.add_argument("--impl", default="ref")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--param-sharding", default="zero",
                    choices=["zero", "tp"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--hlo-out", default=None)
    ap.add_argument("--plan", action="store_true",
                    help="print ExecutionPlan.describe() per cell and "
                         "skip the compiles (fast plan regression smoke)")
    ap.add_argument("--tune", action="store_true",
                    help="with --plan: also print the PlanTuner's top-5 "
                         "candidate table per cell (enumerate+score "
                         "only, nothing runs)")
    args = ap.parse_args()

    archs = all_arch_names() if args.arch == "all" else [args.arch]
    meshes = ["1pod", "2pod"] if args.mesh == "both" else [args.mesh]
    for arch in archs:
        shapes = applicable_shapes(arch) if args.shape == "all" \
            else [args.shape]
        for shape in shapes:
            for mesh_kind in meshes:
                multi = mesh_kind == "2pod"
                pc = None
                if args.hp:
                    base = get_parallel(arch, shape, multi)
                    inner = args.inner or min(args.cp_outer or base.cp, 4)
                    cp = (args.cp_outer or (16 // args.hp) // inner) * inner
                    pc = ParallelConfig(
                        dp=16, hp=args.hp, cp_outer=cp // inner,
                        cp_inner=inner, pods=2 if multi else 1,
                        placement=args.placement or base.placement)
                rec = run_cell(arch, shape, multi_pod=multi, pc=pc,
                               impl=args.impl, remat=args.remat,
                               out_dir=args.out, hlo_out=args.hlo_out,
                               param_sharding=args.param_sharding,
                               tag_extra=args.tag, plan_only=args.plan,
                               tune_table=args.plan and args.tune)
                if args.plan:
                    continue
                c = rec["cost"]
                print(f"[dryrun] {arch} {shape} {rec['mesh']} {rec['pc']}: "
                      f"flops/dev={c['flops']:.3e} "
                      f"coll/dev={rec['collectives']['total']:.3e}B "
                      f"compile={rec['compile_s']}s")


if __name__ == "__main__":
    main()
