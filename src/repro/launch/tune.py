"""PlanTuner launcher: pick the 2D-Attention configuration automatically.

Enumerates the joint (dp, hp, cp_outer×w, placement, grad_accum, remat,
ZeRO) space for a model + device count, prunes with the ExecutionPlan
memory model, ranks with the §4.5 cost model (optionally calibrated
against this host's microbenchmarks), optionally measures the top-K live,
and persists the winner as a ``TunedPlan`` JSON that ``build_plan``
ingests (``launch/train.py --plan-file`` / ``launch/serve.py
--plan-file``).

    python -m repro.launch.tune --arch qwen3-1.7b \
        --num-devices 64 --seq-len 131072 --global-batch 64 \
        [--dp 4] [--budget-gb 16] [--calibrate] [--measure 3] \
        [--out experiments/tuned/qwen3-1.7b.json] [--top 10]

    python -m repro.launch.tune --arch qwen3-1.7b --smoke

Enumeration and scoring never touch device state, so tuning for a
64-chip layout works on a laptop; only ``--measure`` needs the devices
to exist.
"""
from __future__ import annotations

import argparse
import os

from repro.configs import get_config, get_reduced
from repro.launch import args as launch_args
from repro.tune import tune
from repro.tune.calibrate import calibrate


def default_out(arch: str) -> str:
    return os.path.join("experiments", "tuned", f"{arch}.json")


def main():
    ap = argparse.ArgumentParser()
    launch_args.add_arch(
        ap, smoke_help="reduced config, host-sized space (CI smoke)")
    ap.add_argument("--num-devices", type=int, default=64)
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--dp", type=int, default=None,
                    help="pin the data-parallel degree (default: sweep)")
    ap.add_argument("--seq-len", type=int, default=131072)
    ap.add_argument("--global-batch", type=int, default=64)
    ap.add_argument("--budget-gb", type=float, default=16.0)
    ap.add_argument("--calibrate", action="store_true",
                    help="calibrate cost constants from host "
                         "microbenchmarks (persisted, reused)")
    ap.add_argument("--calibration-file", default=None,
                    help="calibration JSON path (default: "
                         "experiments/calibration.json)")
    ap.add_argument("--measure", type=int, default=0, metavar="K",
                    help="measure the analytic top-K live (needs the "
                         "devices to actually exist)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--top", type=int, default=5)
    ap.add_argument("--out", default=None,
                    help="TunedPlan JSON path (default: "
                         "experiments/tuned/<arch>.json)")
    args = ap.parse_args()

    if args.smoke:
        import jax
        cfg = get_reduced(args.arch)
        n_dev = len(jax.devices())
        seq, gb, budget = 256, 8, 1.0
    else:
        cfg = get_config(args.arch)
        n_dev = args.num_devices
        seq, gb, budget = args.seq_len, args.global_batch, args.budget_gb

    const = None
    if args.calibrate:
        const = calibrate(args.calibration_file or
                          os.path.join("experiments", "calibration.json"))
        print(f"[tune] calibrated constants: {const.source} "
              f"(peak={const.peak:.3e} FLOP/s, ici={const.ici:.3e} B/s)")

    result = tune(cfg, num_devices=n_dev, seq_len=seq, global_batch=gb,
                  pods=args.pods, dp=args.dp, memory_budget_gb=budget,
                  const=const, measure_top_k=args.measure,
                  arch=args.arch)
    print(result.table(top=args.top))
    if not result.ranked:
        raise SystemExit("[tune] no feasible candidate — raise "
                         "--budget-gb or change the shape")

    tp = result.tuned_plan(page_size=args.page_size)
    out = args.out or default_out(args.arch)
    tp.save(out)
    print(f"[tune] winner {result.winner.tag} "
          f"(predicted {tp.predicted_s * 1e3:.2f} ms/step"
          + (f", measured {tp.measured_s * 1e3:.2f} ms"
             if tp.measured_s else "")
          + f") -> {out}")
    print(f"[tune] consume with: python -m repro.launch.train "
          f"--arch {args.arch} --plan-file {out}")


if __name__ == "__main__":
    main()
