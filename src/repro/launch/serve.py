"""Serving launcher: continuous-batching paged engine (dense/moe
families) or the fixed-batch contiguous baseline.

    python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --prompt-len 32 --gen 16 --batch 2 --requests 6 --engine paged

``--plan-file plan.json`` consumes a PlanTuner ``TunedPlan`` (layout,
ZeRO, remat and the paged ``page_size`` all come from the cached tuning
run); ``--tune`` searches first and caches when ``--plan-file`` is given.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, get_parallel, get_reduced
from repro.core.plan import build_plan
from repro.core.topology import ParallelConfig
from repro.launch import args as launch_args
from repro.models.decode import decode_step, grow_caches, prefill
from repro.models.model import init_params
from repro.serve import SamplingParams, ServeEngine


def make_generate_fns(cfg, rt):
    """Jitted (prefill, decode_step, trace-counter) triple for
    ``generate``.  Hoist one of these out of any per-group loop —
    ``generate`` builds a fresh triple per call otherwise, and fresh jit
    closures re-trace identical shapes."""
    traces = {"prefill": 0, "decode": 0}

    def _pf(p, bt):
        traces["prefill"] += 1
        return prefill(p, bt, rt, cfg)

    def _step(p, c, t, pos):
        traces["decode"] += 1
        return decode_step(p, c, t, pos, rt, cfg)

    return jax.jit(_pf), jax.jit(_step), traces


def generate(params, cfg, rt, tokens, frames=None, gen: int = 16,
             return_stats: bool = False, fns=None):
    """Fixed-batch greedy baseline.  tokens: (B, S_prompt).

    The cache is padded to the full ``prompt + gen`` extent once, before
    the loop, so every decode step runs at one shape — ``decode_step``
    traces exactly once per stream (asserted in tests via
    ``return_stats``); the paged engine gets the same guarantee from its
    block reservation.  Pass ``fns=make_generate_fns(cfg, rt)`` when
    calling in a loop so compiled steps are reused across groups.
    """
    b, s = tokens.shape
    batch = {"tokens": tokens}
    if frames is not None:
        batch["frames"] = frames
    pf, step, traces = fns or make_generate_fns(cfg, rt)
    logits, caches = pf(params, batch)
    caches = grow_caches(cfg, caches, gen)
    out = [jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)]
    for t in range(gen - 1):
        logits, caches = step(params, caches, out[-1], jnp.int32(s + t))
        out.append(jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32))
    toks = jnp.concatenate(out, axis=1)
    return (toks, traces) if return_stats else toks


def main():
    ap = argparse.ArgumentParser()
    launch_args.add_arch(ap, smoke_help="reduced config on 1 device")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--batch", type=int, default=2,
                    help="engine decode slots / baseline batch size")
    ap.add_argument("--requests", type=int, default=0,
                    help="request-stream length (default: --batch)")
    ap.add_argument("--engine", choices=["paged", "fixed"], default=None,
                    help="default: paged for dense/moe, fixed otherwise")
    ap.add_argument("--page-size", type=int, default=None,
                    help="paged-KV page size (default: 16, or the tuned "
                         "plan's value under --plan-file)")
    ap.add_argument("--prefill-chunk", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    launch_args.add_plan_source(ap)
    args = ap.parse_args()

    if args.smoke:
        cfg = get_reduced(args.arch)
        pc = ParallelConfig()
        devices = jax.devices()[:1]
    else:
        cfg = get_config(args.arch)
        pc = get_parallel(args.arch, "decode_32k", False)
        devices = None

    tuned = None
    if args.tune or args.plan_file:
        tuned = launch_args.resolve_tuned(
            args, cfg, seq=args.prompt_len + args.gen, gb=args.batch,
            smoke=args.smoke, accums=(1,),
            page_size=args.page_size or 16, tag="serve")
        pc, devices = tuned.parallel(), None
        if args.page_size is None:        # explicit flag beats the file
            args.page_size = tuned.page_size
    args.page_size = args.page_size or 16
    plan = build_plan(cfg, pc, devices=devices, tuned=tuned)
    print(plan.describe())
    mesh, rt = plan.mesh, plan.rt

    engine_kind = args.engine or (
        "paged" if cfg.family in ("dense", "moe") else "fixed")
    n_req = args.requests or args.batch
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, size=args.prompt_len)
               for _ in range(n_req)]

    if engine_kind == "paged":
        spec = plan.serve_spec(
            page_size=args.page_size, max_batch=args.batch,
            max_seq_len=args.prompt_len + args.gen,
            prefill_chunk=args.prefill_chunk)
        sp = SamplingParams(temperature=args.temperature,
                            top_k=args.top_k, top_p=args.top_p)
        with mesh:
            eng = ServeEngine(plan, params, spec)
            for p in prompts:
                eng.submit(p, sp, max_new_tokens=args.gen)
            res = eng.run()
        lats = sorted(r["latency_s"] for r in res["requests"].values())
        p50 = lats[len(lats) // 2]
        p99 = lats[min(len(lats) - 1, int(len(lats) * 0.99))]
        print(f"paged engine: {res['generated']} tokens from {n_req} "
              f"requests in {res['wall_s']:.2f}s "
              f"({res['tokens_per_s']:.1f} tok/s, p50={p50:.2f}s "
              f"p99={p99:.2f}s, {res['engine_steps']} engine steps, "
              f"decode traces={eng.decode_traces})")
        first = res["requests"][0]["tokens"]
        print(f"request 0: {first[:12]}")
    else:
        frames = None
        if cfg.family == "encdec":
            frames = jax.random.normal(
                jax.random.PRNGKey(2),
                (args.batch, cfg.enc_frames, cfg.d_model), jnp.float32)
        done = 0
        t0 = time.perf_counter()
        with mesh:
            fns = make_generate_fns(cfg, rt)    # one compile across groups
            for i in range(0, n_req, args.batch):
                group = prompts[i:i + args.batch]
                tokens = jnp.asarray(np.stack(
                    group + [group[-1]] * (args.batch - len(group))))
                out = jax.device_get(generate(params, cfg, rt, tokens,
                                              frames, args.gen, fns=fns))
                done += len(group) * args.gen
        dt = time.perf_counter() - t0
        print(f"fixed batch: generated {done} tokens in {dt:.2f}s "
              f"({done / dt:.1f} tok/s)")
        print(out[:, :12])


if __name__ == "__main__":
    main()
