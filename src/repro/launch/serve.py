"""Serving launcher: batched prefill + greedy decode loop.

    python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --prompt-len 32 --gen 16 --batch 2
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_parallel, get_reduced
from repro.core.plan import build_plan
from repro.core.topology import ParallelConfig
from repro.models.decode import decode_step, grow_caches, prefill
from repro.models.model import init_params


def generate(params, cfg, rt, tokens, frames=None, gen: int = 16):
    """Greedy generation.  tokens: (B, S_prompt)."""
    b, s = tokens.shape
    batch = {"tokens": tokens}
    if frames is not None:
        batch["frames"] = frames
    pf = jax.jit(lambda p, bt: prefill(p, bt, rt, cfg))
    step = jax.jit(lambda p, c, t, pos: decode_step(p, c, t, pos, rt, cfg))
    logits, caches = pf(params, batch)
    caches = grow_caches(cfg, caches, gen)
    out = [jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)]
    for t in range(gen - 1):
        logits, caches = step(params, caches, out[-1], jnp.int32(s + t))
        out.append(jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32))
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()

    if args.smoke:
        cfg = get_reduced(args.arch)
        pc = ParallelConfig()
        devices = jax.devices()[:1]
    else:
        cfg = get_config(args.arch)
        pc = get_parallel(args.arch, "decode_32k", False)
        devices = None
    plan = build_plan(cfg, pc, devices=devices)
    print(plan.describe())
    mesh, rt = plan.mesh, plan.rt

    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)
    frames = None
    if cfg.family == "encdec":
        frames = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.enc_frames, cfg.d_model), jnp.float32)
    with mesh:
        t0 = time.perf_counter()
        out = jax.device_get(generate(params, cfg, rt, tokens, frames,
                                      args.gen))
        dt = time.perf_counter() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(out[:, :12])


if __name__ == "__main__":
    main()
