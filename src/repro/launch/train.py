"""Training launcher.

Single-host (CPU/GPU dev) and multi-host SPMD: on a real fleet every host
runs this same script; ``jax.distributed.initialize()`` picks up the
standard cluster env (COORDINATOR_ADDRESS / NUM_PROCESSES / PROCESS_ID or
TPU metadata).  All execution decisions — mesh, placement, hybrid ZeRO,
remat, microbatching — are made once by ``build_plan`` and printed via
``plan.describe()``.

    python -m repro.launch.train --arch qwen3-1.7b --steps 100 \
        --seq-len 4096 --global-batch 256 --hp 8 --inner 2 \
        --grad-accum 4 --ckpt-dir /tmp/ckpt --save-every 20 [--smoke]

``--smoke`` swaps in the reduced config + a 1-device mesh — the same code
path end to end, laptop-sized.

Checkpointing (``--ckpt-dir``): async per-shard saves every
``--save-every`` steps through the plan-aware ``CheckpointManager``;
SIGTERM flushes a final checkpoint at the next step boundary
(``PreemptionGuard``), and a relaunch resumes from the latest step —
even under a *different* plan (elastic restore-time resharding).
``--no-resume`` starts fresh.

``--pack`` trains on packed documents (``PackedLM``): variable-length
documents bin-packed into the sequence window with per-document
block-causal masking through the 2D-Attention stack; ``--mean-doc-len``
scales the document-length distribution and the cost model's packing
term (default ``seq_len // 4``).

``--offload-chunks N`` enables FPDT sequence-chunk pipelining: the plan's
memory model charges only the HBM-resident chunk fraction (active + next)
and reports the PCIe wire-time floor plus ``max_seq@budget`` in
``plan.describe()``.  The PlanTuner proposes a depth automatically when
the resident plan does not fit the budget.

PlanTuner integration: ``--plan-file plan.json`` consumes a persisted
``TunedPlan`` (no search — the cached winner supplies dp/hp/cp/placement,
grad-accum, remat and ZeRO); ``--tune`` runs the enumerate+score search
for the attached devices first and, when ``--plan-file`` is also given,
caches the winner there for the next run.
"""
from __future__ import annotations

import argparse
import logging

import jax

from repro.configs import get_config, get_parallel, get_reduced
from repro.core.plan import build_plan
from repro.core.topology import ParallelConfig
from repro.launch import args as launch_args
from repro.launch.args import resolve_tuned   # noqa: F401  (re-export)
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    launch_args.add_arch(ap, smoke_help="reduced config on 1 device")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=4096)
    ap.add_argument("--global-batch", type=int, default=256)
    ap.add_argument("--grad-accum", type=int, default=None,
                    help="microbatches per step (default: 1, or the "
                         "tuned plan's value under --plan-file)")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--hp", type=int, default=None)
    ap.add_argument("--inner", type=int, default=None)
    ap.add_argument("--placement", default=None)
    ap.add_argument("--remat", default=None,
                    help="none|full|scpp|auto (default: model config)")
    ap.add_argument("--pack", action="store_true",
                    help="packed-document training: bin-packed variable-"
                         "length documents with per-document block-causal "
                         "masking (PackedLM)")
    ap.add_argument("--mean-doc-len", type=int, default=None,
                    help="expected mean document length of the packed "
                         "stream (default: seq_len // 4); sets the data "
                         "source's length range and the cost model's "
                         "packing term")
    ap.add_argument("--offload-chunks", type=int, default=None,
                    help="FPDT sequence-chunk pipelining: stream the "
                         "sequence through attention in this many chunks "
                         "with inactive K/V staged in host memory "
                         "(default: 1 = fully resident, or the tuned "
                         "plan's value under --plan-file)")
    launch_args.add_plan_source(ap)
    launch_args.add_checkpointing(ap)
    ap.add_argument("--distributed", action="store_true",
                    help="call jax.distributed.initialize()")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO)
    if args.distributed:
        jax.distributed.initialize()

    if args.smoke:
        cfg = get_reduced(args.arch)
        pc = ParallelConfig()
        devices = jax.devices()[:1]
        seq, gb = min(args.seq_len, 128), min(args.global_batch, 8)
    else:
        cfg = get_config(args.arch)
        pc = get_parallel(args.arch, "train_4k", False)
        if args.hp:
            inner = args.inner or min(16 // args.hp, 4)
            cp = 16 // args.hp
            pc = ParallelConfig(dp=pc.dp, hp=args.hp, cp_outer=cp // inner,
                                cp_inner=inner,
                                placement=args.placement or pc.placement)
        devices = None
        seq, gb = args.seq_len, args.global_batch

    mean_doc = args.mean_doc_len or max(8, seq // 4)
    tuned = None
    grad_accum = args.grad_accum
    if args.tune or args.plan_file:
        tuned = resolve_tuned(args, cfg, seq=seq, gb=gb, smoke=args.smoke,
                              packing=min(1.0, mean_doc / seq)
                              if args.pack else 1.0)
        pc = tuned.parallel()
        devices = None
        if grad_accum is None and gb % tuned.grad_accum:
            print(f"[train] plan's grad_accum={tuned.grad_accum} does "
                  f"not divide global_batch={gb}; using 1 "
                  f"(pass --grad-accum to choose)")
            grad_accum = 1
    n = pc.num_devices
    assert len(jax.devices()) >= n, \
        f"need {n} devices, have {len(jax.devices())}"

    plan = build_plan(cfg, pc, OptConfig(lr=args.lr,
                                         total_steps=args.steps),
                      devices=devices, grad_accum=grad_accum,
                      remat=args.remat, seq_len=seq, global_batch=gb,
                      packed=args.pack,
                      mean_doc_len=mean_doc if args.pack else None,
                      offload_chunks=args.offload_chunks, tuned=tuned)
    print(plan.describe())
    trainer = Trainer(
        plan, plan.data_config(seq, gb),
        TrainerConfig(num_steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=launch_args.save_every(args),
                      resume=args.resume))
    losses = trainer.run()
    print(f"final loss: {losses[-1]:.4f} "
          f"(median step {trainer.monitor.median:.3f}s)")
    rep = trainer.monitor.report()
    if rep["stragglers"]:
        print(f"stragglers flagged: {rep['stragglers']}")


if __name__ == "__main__":
    main()
