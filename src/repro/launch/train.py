"""Training launcher.

Single-host (CPU/GPU dev) and multi-host SPMD: on a real fleet every host
runs this same script; ``jax.distributed.initialize()`` picks up the
standard cluster env (COORDINATOR_ADDRESS / NUM_PROCESSES / PROCESS_ID or
TPU metadata).  All execution decisions — mesh, placement, hybrid ZeRO,
remat, microbatching — are made once by ``build_plan`` and printed via
``plan.describe()``.

    python -m repro.launch.train --arch qwen3-1.7b --steps 100 \
        --seq-len 4096 --global-batch 256 --hp 8 --inner 2 \
        --grad-accum 4 --ckpt-dir /tmp/ckpt [--smoke]

``--smoke`` swaps in the reduced config + a 1-device mesh — the same code
path end to end, laptop-sized.
"""
from __future__ import annotations

import argparse
import logging

import jax

from repro.configs import get_config, get_parallel, get_reduced
from repro.core.plan import build_plan
from repro.core.topology import ParallelConfig
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=4096)
    ap.add_argument("--global-batch", type=int, default=256)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--hp", type=int, default=None)
    ap.add_argument("--inner", type=int, default=None)
    ap.add_argument("--placement", default=None)
    ap.add_argument("--remat", default=None,
                    help="none|full|scpp|auto (default: model config)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on 1 device")
    ap.add_argument("--distributed", action="store_true",
                    help="call jax.distributed.initialize()")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO)
    if args.distributed:
        jax.distributed.initialize()

    if args.smoke:
        cfg = get_reduced(args.arch)
        pc = ParallelConfig()
        devices = jax.devices()[:1]
        seq, gb = min(args.seq_len, 128), min(args.global_batch, 8)
    else:
        cfg = get_config(args.arch)
        pc = get_parallel(args.arch, "train_4k", False)
        if args.hp:
            inner = args.inner or min(16 // args.hp, 4)
            cp = 16 // args.hp
            pc = ParallelConfig(dp=pc.dp, hp=args.hp, cp_outer=cp // inner,
                                cp_inner=inner,
                                placement=args.placement or pc.placement)
        n = pc.num_devices
        assert len(jax.devices()) >= n, \
            f"need {n} devices, have {len(jax.devices())}"
        devices = None
        seq, gb = args.seq_len, args.global_batch

    plan = build_plan(cfg, pc, OptConfig(lr=args.lr,
                                         total_steps=args.steps),
                      devices=devices, grad_accum=args.grad_accum,
                      remat=args.remat, seq_len=seq, global_batch=gb)
    print(plan.describe())
    trainer = Trainer(
        plan, plan.data_config(seq, gb),
        TrainerConfig(num_steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every))
    losses = trainer.run()
    print(f"final loss: {losses[-1]:.4f} "
          f"(median step {trainer.monitor.median:.3f}s)")
    rep = trainer.monitor.report()
    if rep["stragglers"]:
        print(f"stragglers flagged: {rep['stragglers']}")


if __name__ == "__main__":
    main()
