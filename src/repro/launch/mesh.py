"""Production mesh construction.

``make_production_mesh`` is the canonical entry: a 16×16 pod (256 chips) or
a 2×16×16 two-pod slice (512 chips).  It is a *function* so importing this
module never touches jax device state.

``production_runtime`` refines the production mesh into the 5-axis
LoongTrain mesh (pod, data, head, outer, inner) for a given ParallelConfig
without changing device order — placement (head-first vs context-first)
decides which sub-axis is ICI-minor (see core/topology.py).
"""
from __future__ import annotations

import jax

from repro.core.runtime import Runtime
from repro.core.topology import BATCH_AXES, ParallelConfig, refine_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def production_runtime(pc: ParallelConfig, *, multi_pod: bool = False,
                       impl: str = "auto",
                       batch_shardable: bool = True) -> Runtime:
    base = make_production_mesh(multi_pod=multi_pod)
    mesh = refine_mesh(base, pc)
    return Runtime(mesh=mesh, pc=pc, impl=impl,
                   batch_axes=BATCH_AXES if batch_shardable else ())
