"""Production mesh construction.

``make_production_mesh`` is the canonical entry: a 16×16 pod (256 chips) or
a 2×16×16 two-pod slice (512 chips).  It is a *function* so importing this
module never touches jax device state.

``production_plan`` refines the production mesh into the 5-axis LoongTrain
mesh via ``core/plan.build_plan`` — placement (head-first vs context-first)
decides which sub-axis is ICI-minor (see core/topology.py), and the plan
owns every downstream decision (ZeRO extent, remat, shardings).
"""
from __future__ import annotations

import jax

from repro.core.plan import ExecutionPlan, build_plan
from repro.core.topology import ParallelConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def production_plan(cfg, pc: ParallelConfig, *, multi_pod: bool = False,
                    impl: str = "auto", **kw) -> ExecutionPlan:
    base = make_production_mesh(multi_pod=multi_pod)
    return build_plan(cfg, pc, base_mesh=base, impl=impl, **kw)
