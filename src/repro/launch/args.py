"""Shared launcher argument builders + PlanTuner plan-file resolution.

``train.py``, ``serve.py``, ``dryrun.py`` and ``tune.py`` used to each
re-declare the config / ``--plan-file`` / ``--tune`` flag set (and the
resolution logic behind it) — one builder per flag family ends the
drift, and gives new flags (``--ckpt-dir``/``--resume``/``--save-every``)
a single home.  ``scripts/check_docs.py`` statically unions this
module's ``add_argument`` calls into each importing launcher's known
flag set, so documented commands stay verifiable.
"""
from __future__ import annotations

import os


def add_arch(ap, *, arch_help: str = "architecture id",
             smoke_help: str | None = None):
    """``--arch`` (required) and, when ``smoke_help`` is given,
    ``--smoke`` — the config-selection pair every launcher starts
    with."""
    ap.add_argument("--arch", required=True, help=arch_help)
    if smoke_help is not None:
        ap.add_argument("--smoke", action="store_true", help=smoke_help)


def add_plan_source(ap):
    """``--tune`` / ``--plan-file``: the PlanTuner plan source pair
    consumed by ``resolve_tuned``."""
    ap.add_argument("--tune", action="store_true",
                    help="search the plan space for the attached devices "
                         "first")
    ap.add_argument("--plan-file", default=None,
                    help="TunedPlan JSON: consumed when it exists, "
                         "written by --tune otherwise")


def add_checkpointing(ap):
    """``--ckpt-dir`` / ``--save-every`` / ``--resume``: the trainer's
    checkpoint surface (async sharded saves, elastic resume)."""
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint directory: per-shard async saves, "
                         "auto-resume from the latest step")
    ap.add_argument("--save-every", type=int, default=None,
                    help="async-save cadence in steps (default 50)")
    ap.add_argument("--ckpt-every", type=int, default=None,
                    help="deprecated alias of --save-every")
    ap.add_argument("--resume", dest="resume", action="store_true",
                    default=True,
                    help="resume from the latest checkpoint in --ckpt-dir "
                         "(default)")
    ap.add_argument("--no-resume", dest="resume", action="store_false",
                    help="start fresh even when --ckpt-dir holds "
                         "checkpoints")


def save_every(args) -> int:
    """The effective save cadence: ``--save-every`` wins, the deprecated
    ``--ckpt-every`` alias still works, default 50."""
    if args.save_every is not None:
        return args.save_every
    if args.ckpt_every is not None:
        return args.ckpt_every
    return 50


def resolve_tuned(args, cfg, *, seq: int, gb: int, smoke: bool,
                  packing: float = 1.0, accums=None, page_size=None,
                  tag: str = "train"):
    """--plan-file / --tune resolution shared by train and serve: a
    cached TunedPlan wins; otherwise search (and cache to --plan-file
    when given).

    ``packing`` is the packed-workload fraction (mean_doc_len / seq_len)
    the cost model scores with — 1.0 for unpacked runs.  ``accums``
    restricts the search's grad-accum candidates (serve pins ``(1,)``);
    ``page_size`` is recorded in the persisted plan (serve).
    """
    import jax
    from repro.tune import TunedPlan, tune
    if args.plan_file and os.path.exists(args.plan_file):
        tuned = TunedPlan.load(args.plan_file)
        assert tuned.arch == args.arch, \
            f"{args.plan_file} was tuned for {tuned.arch!r}, " \
            f"not {args.arch!r} — delete it or pass the matching --arch"
        print(f"[{tag}] tuned plan from {args.plan_file}: "
              f"dp{tuned.dp}/hp{tuned.hp}/cp{tuned.cp_outer}x"
              f"{tuned.cp_inner}/{tuned.placement} accum="
              f"{tuned.grad_accum} remat={tuned.remat} "
              f"zero={tuned.zero} (no re-search)")
        if args.tune:
            print(f"[{tag}] --tune ignored: cached plan exists "
                  f"(delete {args.plan_file} to re-search)")
        if (tuned.seq_len, tuned.global_batch) != (seq, gb):
            print(f"[{tag}] note: plan was tuned for seq="
                  f"{tuned.seq_len} gb={tuned.global_batch}, "
                  f"running seq={seq} gb={gb}")
        return tuned
    kw = {}
    if accums is not None:
        kw["accums"] = accums
    result = tune(cfg, num_devices=len(jax.devices()), seq_len=seq,
                  global_batch=gb,
                  memory_budget_gb=1.0 if smoke else 16.0,
                  packing=packing, arch=args.arch, **kw)
    print(result.table())
    tuned = result.tuned_plan(**({"page_size": page_size}
                                 if page_size is not None else {}))
    if args.plan_file:
        tuned.save(args.plan_file)
        print(f"[{tag}] tuned plan cached -> {args.plan_file}")
    return tuned
