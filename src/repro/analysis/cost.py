"""LoongTrain §4.5 cost model — the one shared implementation.

The paper evaluates on A100 + 4×HDR nodes; we target a v5e pod, so the
model is re-based on ICI:

* peak = 197 TF/s bf16/chip;  per-link ICI = 50 GB/s.
* "intra-node NVLINK" ≙ collectives over the ICI-*minor* mesh axis
  (single-hop neighbours): full link bw.
* "inter-node NIC"    ≙ collectives over major axes: modelled at half
  effective bw (multi-hop average on the torus) — the placement trade-off
  of §4.4 survives with the same structure.
* Double ring: inner ring uses one torus dimension, outer the other; both
  can run concurrently (the "use all NICs" insight).

Consumers: the PlanTuner (``repro/tune``) scores candidate
``ExecutionPlan``s with it, the roofline (``repro/analysis/roofline.py``)
shares its hardware constants, and the paper-table benches
(``benchmarks/run.py`` t2–t5) print it.  The formulas are *models*, cross-checked against dry-run
collective bytes (see EXPERIMENTS.md §Roofline); the ``CostConstants``
α factors are calibrated by on-host microbenchmarks
(``repro/tune/calibrate.py``) and persisted, so predicted step times land
in the measured ballpark on whatever host runs the tuner.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CostConstants:
    """Hardware constants + calibration factors for the §4.5 model.

    The defaults are nominal TPU v5e.  ``repro/tune/calibrate.py``
    rescales them from measured microbenchmarks (matmul, HBM copy,
    collective round-trip) so absolute predictions track the host the
    tuner runs on; the *relative* placement trade-offs are bandwidth
    ratios and survive any uniform rescale.
    """
    peak: float = 197e12          # bf16 FLOP/s per chip
    hbm: float = 819e9            # HBM B/s per chip
    ici: float = 50e9             # B/s per ICI link
    pcie: float = 16e9            # host↔device B/s (chunk-offload wire)
    major_penalty: float = 0.5    # effective bw multiplier, ICI-major axes
    bytes_per_el: int = 2         # bf16
    #: measured/nominal efficiency factors (calibration output)
    alpha_flops: float = 1.0      # achieved matmul FLOP/s / peak
    alpha_p2p: float = 1.0        # achieved ring p2p bw / nominal
    alpha_a2a: float = 1.0        # achieved AlltoAll bw / nominal
    alpha_rsag: float = 1.0       # achieved RS/AG bw / nominal
    alpha_pcie: float = 1.0       # achieved host↔device bw / nominal
    source: str = "v5e-nominal"

    @property
    def flops(self) -> float:
        return self.peak * self.alpha_flops


V5E = CostConstants()

# Module-level aliases — single source of truth for every consumer that
# previously duplicated these numbers (analysis/roofline.py and the
# now-deprecated benchmarks/analytic.py shim).
PEAK = V5E.peak
HBM_BW = V5E.hbm
ICI = V5E.ici
MAJOR_PENALTY = V5E.major_penalty
BYTES = V5E.bytes_per_el


@dataclasses.dataclass(frozen=True)
class AttnCase:
    s: int                 # sequence length
    d: int = 4096          # hidden
    h: int = 32            # query heads
    h_kv: int = 32         # kv heads (MHA: == h)
    sp: int = 64           # total sequence-parallel degree
    hp: int = 1
    w: int = 4             # inner ring size
    placement: str = "head_first"
    causal: bool = True
    #: packed-document fraction of the causal band that is attendable
    #: (≈ mean_doc_len / seq_len; Σlᵢ²/S² exactly).  Scales the attention
    #: FLOPs only — the KV chunks still rotate whole, so packing shifts
    #: the compute/communication balance the tuner ranks on.  The kernel
    #: realizes the reduction via doc-aware block skipping.
    packing: float = 1.0
    #: FPDT chunk pipeline: sequence chunks streamed through attention
    #: with inactive K/V in host memory (1 = fully resident).  Adds the
    #: PCIe wire term ``offload_wire_time`` that the tuner trades
    #: against the HBM the offload frees.
    offload_chunks: int = 1

    @property
    def cp(self) -> int:
        return self.sp // self.hp

    @property
    def hd(self) -> int:
        return self.d // self.h

    @classmethod
    def from_plan(cls, plan, *, seq_len: int | None = None) -> "AttnCase":
        """Cost-model case straight from an ``ExecutionPlan`` — the tuner
        and roofline query one object instead of re-deriving dims."""
        cfg, pc = plan.cfg, plan.pc
        s = seq_len or plan.seq_len
        assert s is not None, "plan has no seq_len; pass seq_len="
        return cls(s=s, d=cfg.d_model, h=cfg.n_heads,
                   h_kv=cfg.n_kv_heads, sp=pc.sp, hp=pc.hp,
                   w=pc.cp_inner, placement=pc.placement,
                   packing=getattr(plan, "packing_frac", 1.0),
                   offload_chunks=getattr(plan, "offload_chunks", 1))


def attn_flops_per_device(c: AttnCase) -> float:
    """Useful attention FLOPs per device per layer fwd (causal halved;
    packed streams scale by the attendable fraction)."""
    full = 4.0 * c.s * c.s * c.d          # QK^T + PV, MACs×2
    if c.causal:
        full *= 0.5 * c.packing
    return full / c.sp


def comp_time_fwd(c: AttnCase, const: CostConstants = V5E) -> float:
    """One ring micro-step of compute (paper: α S²D/(cp·sp))."""
    per_step = attn_flops_per_device(c) / c.cp
    return per_step / const.flops


def kv_chunk_bytes(c: AttnCase, const: CostConstants = V5E) -> float:
    """Paper §4.5.3: Size(kv) = max(Hkv, hp)/H × (2 tensors)·S·D/sp ·bytes."""
    h_eff = max(c.h_kv, c.hp)
    return h_eff / c.h * 2.0 * c.s * c.d / c.sp * const.bytes_per_el


def p2p_time(c: AttnCase, *, inner: bool, const: CostConstants = V5E) -> float:
    bw = const.ici * const.alpha_p2p
    # context-first: inner ring is ICI-minor (full bw); head-first: the head
    # axis is minor, pushing rings to major axes.
    if c.placement == "context_first":
        if not inner:
            bw *= const.major_penalty
    else:
        bw *= const.major_penalty
    return kv_chunk_bytes(c, const) / bw


def alltoall_time(c: AttnCase, const: CostConstants = V5E) -> float:
    """Paper §4.5.4: Σ_{q,k,v,out} size × (hp-1)/hp, over the hp axis."""
    if c.hp == 1:
        return 0.0
    # Size(q) el = 2SD/sp
    q = out = 2.0 * c.s * c.d / c.sp * const.bytes_per_el / 2
    kv = kv_chunk_bytes(c, const)                        # K and V together
    vol = (q + out + kv) * (c.hp - 1) / c.hp
    bw = const.ici if c.placement == "head_first" \
        else const.ici * const.major_penalty
    return vol * (1.0 / (bw * const.alpha_a2a))


def attention_op_time(c: AttnCase, *, backward: bool = False,
                      const: CostConstants = V5E) -> float:
    """Paper's overlap model: T = T_a2a + (cp/w)·[A(w-1) + B]."""
    t_comp = comp_time_fwd(c, const) * (3.0 if backward else 1.0)
    t_inner = p2p_time(c, inner=True, const=const) * (2.0 if backward
                                                      else 1.0)
    t_outer = p2p_time(c, inner=False, const=const) * (2.0 if backward
                                                       else 1.0)
    w = min(c.w, c.cp)
    n_outer = c.cp // w
    a = max(t_comp, t_inner)
    b = max(t_comp, t_outer)
    ring = n_outer * (a * (w - 1) + b)
    return alltoall_time(c, const) * (2.0 if backward else 1.0) + ring


def offload_wire_time(c: AttnCase, const: CostConstants = V5E) -> float:
    """Per-layer host↔device wire seconds of the FPDT chunk pipeline.

    With C chunks, KV chunk j is re-fetched from host for every q-chunk
    i ≥ j — ≈ (C+1)/2 copies of the local K+V per direction (forward and
    backward each run the full causal pair schedule) — plus ~4 q-sized
    one-shot tensors (q/out/lse staging forward, do + grads home on the
    backward).  The copies are double-buffered against ring steps, so
    this is a *floor* the attention time is maxed against, not an
    additive serial term.
    """
    if c.offload_chunks <= 1:
        return 0.0
    kv = kv_chunk_bytes(c, const)
    q = 2.0 * c.s * c.d / c.sp * const.bytes_per_el
    refetch = (c.offload_chunks + 1) / 2.0
    wire = 2.0 * refetch * kv + 4.0 * q
    return wire / (const.pcie * const.alpha_pcie)


def layer_linear_flops(d: int, d_ff: int, s: int, h: int, hd: int,
                       h_kv: int) -> float:
    qkvo = 2.0 * s * d * (h * hd + 2 * h_kv * hd + h * hd)
    mlp = 2.0 * s * d * d_ff * 3
    return qkvo + mlp


def layer_step_time(c: AttnCase, *, d_ff: int = 11008,
                    remat: str = "scpp",
                    const: CostConstants = V5E) -> dict:
    """Per-layer modelled wall seconds of one train step (fwd + bwd),
    split into terms.  ``remat`` mirrors the model stack's policies:

    * ``none`` — nothing recomputed;
    * ``scpp`` — Selective Checkpoint++ (§5.2): linear fwd recomputed,
      attention saved;
    * ``full`` — full-layer checkpointing: linear *and* attention fwd
      recomputed during backward.
    """
    lin_flops = layer_linear_flops(c.d, d_ff, c.s, c.h, c.hd, c.h_kv) / c.sp
    t_lin = lin_flops * 3.0 / const.flops
    if remat in ("scpp", "full"):
        t_lin += lin_flops / const.flops
    t_attn = attention_op_time(c, const=const) \
        + attention_op_time(c, backward=True, const=const)
    if remat == "full":
        t_attn += attention_op_time(c, const=const)
    t_wire = offload_wire_time(c, const)
    # chunk H2D/D2H copies are double-buffered against ring steps: the
    # pipeline runs at whichever of compute or wire is slower
    t_attn = max(t_attn, t_wire)
    return {"linear_s": t_lin, "attn_s": t_attn,
            "offload_s": t_wire,
            "lin_flops": lin_flops,
            "attn_flops": attn_flops_per_device(c)}


def zero_collective_time(n_params: int, extent: int, *,
                         const: CostConstants = V5E) -> float:
    """Per-step hybrid-ZeRO wire time: one grad reduce-scatter + one
    param all-gather over the sharding group — ring-algorithm wire bytes
    ``2·(g-1)/g·N·bytes`` (AMSP's latency argument: smaller extents move
    marginally fewer bytes but far fewer hops; we fold hops into the
    same (g-1)/g factor, which preserves the smaller-is-cheaper order).
    """
    if extent <= 1:
        # grads still all-reduce over dp in spirit, but that cost is
        # extent-independent; the *differential* term is what the tuner
        # ranks on, so replica contributes zero.
        return 0.0
    wire = 2.0 * (extent - 1) / extent * n_params * const.bytes_per_el
    return wire / (const.ici * const.alpha_rsag)


#: fixed per-microbatch dispatch/loop overhead charged by the step-time
#: model — grad-accum trades activation memory for this (small) serial
#: cost, so the tuner prefers the smallest feasible accum.
ACCUM_OVERHEAD_S = 20e-6


def train_step_time(c: AttnCase, *, d_ff: int = 11008, n_layers: int = 32,
                    remat: str = "scpp", seqs_per_group: float = 1.0,
                    n_params: int = 0, zero_extent: int = 1,
                    grad_accum: int = 1,
                    const: CostConstants = V5E) -> dict:
    """Modelled wall seconds of one full train step.

    ``seqs_per_group`` — sequences each sp group processes per step
    (``global_batch / (pods·dp)``); the attention/linear terms scale with
    it, the ZeRO collectives and accum overhead do not.
    """
    layer = layer_step_time(c, d_ff=d_ff, remat=remat, const=const)
    t_math = (layer["linear_s"] + layer["attn_s"]) * n_layers \
        * seqs_per_group
    t_zero = zero_collective_time(n_params, zero_extent, const=const)
    t_accum = ACCUM_OVERHEAD_S * max(grad_accum - 1, 0)
    return {"total_s": t_math + t_zero + t_accum,
            "math_s": t_math, "zero_s": t_zero, "accum_s": t_accum,
            "linear_s": layer["linear_s"] * n_layers * seqs_per_group,
            "attn_s": layer["attn_s"] * n_layers * seqs_per_group,
            "offload_s": layer["offload_s"] * n_layers * seqs_per_group}


def end_to_end_mfu(c: AttnCase, *, d_ff: int = 11008, n_layers: int = 32,
                   sc_pp: bool = True, const: CostConstants = V5E) -> float:
    """Modelled training MFU for a LLaMA-7B-like stack on sp devices.

    Non-attention compute is assumed perfectly overlapped/balanced (it has
    no sequence-length-dependent communication under hybrid ZeRO);
    attention uses the overlap model above.  Without SC++, the attention
    forward is recomputed during backward (full-layer gradient
    checkpointing); with SC++ it is not (the paper's §5.2 point).
    """
    # full-layer remat recomputes the linear fwd either way (activation
    # memory at 1M tokens forces checkpointing; SC++ only spares attention)
    layer = layer_step_time(c, d_ff=d_ff,
                            remat="scpp" if sc_pp else "full", const=const)
    useful = (layer["lin_flops"] + layer["attn_flops"]) * 3.0  # fwd + 2×bwd
    t_total = layer["linear_s"] + layer["attn_s"]
    return useful / (t_total * const.flops)
