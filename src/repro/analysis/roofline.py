"""Roofline model (TPU v5e) over dry-run artifacts.

Per (arch × shape × mesh):

    compute term    = HLO_FLOPs_total / (chips × 197e12 FLOP/s)
    memory term     = HLO_bytes_total / (chips × 819e9 B/s)
    collective term = collective_bytes_total / (chips × 50e9 B/s)

``cost_analysis``/HLO parsing run on the *partitioned* (per-device) module,
so totals are per-device values × chips, and the terms reduce to
per-device / peak.  MODEL_FLOPS = 6·N·(tokens) for training (2·N·tokens for
prefill/decode), with N_active for MoE; the ratio MODEL_FLOPS / HLO_FLOPs
exposes remat / masking / padding waste.
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax

from repro.analysis import cost

# Hardware constants come from the shared cost model (one source of truth
# with the §4.5 analytic model and the PlanTuner).
PEAK_FLOPS = cost.PEAK     # bf16 / chip
HBM_BW = cost.HBM_BW       # B/s / chip
ICI_BW = cost.ICI          # B/s / link


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_total: float
    useful_ratio: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """How much of the step is the *ideal* compute time — the score."""
        ideal = (self.model_flops / self.hlo_flops_total) * self.compute_s \
            if self.hlo_flops_total else 0.0
        return ideal / self.bound_s if self.bound_s else 0.0


def count_params(cfg) -> tuple[int, int]:
    """(total, active) parameter counts (active < total only for MoE)."""
    from repro.models.model import init_params
    struct = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))
    total = sum(int(x.size) for x in jax.tree.leaves(struct))
    active = total
    if cfg.moe is not None:
        flat = jax.tree_util.tree_flatten_with_path(struct)[0]
        expert = sum(int(v.size) for k, v in flat
                     if "'w1'" in jax.tree_util.keystr(k)
                     or "'w2'" in jax.tree_util.keystr(k)
                     or "'w3'" in jax.tree_util.keystr(k))
        active = total - expert \
            + int(expert * cfg.moe.top_k / cfg.moe.n_experts)
    return total, active


def model_flops(cfg, shape_kind: str, seq_len: int, global_batch: int,
                n_active: int) -> float:
    """Paper-style useful FLOPs (attention halved for causal is *not*
    added here — 6·N·D is the standard dense-matmul accounting)."""
    if shape_kind == "train":
        tokens = seq_len * global_batch
        return 6.0 * n_active * tokens
    if shape_kind == "prefill":
        tokens = seq_len * global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * global_batch


def terms_from_record(rec: dict) -> RooflineTerms:
    chips = rec["chips"]
    flops_dev = rec["cost"]["flops"]
    bytes_dev = rec["cost"].get("bytes_accessed", 0.0)
    # wire bytes (ring-algorithm per-op multipliers) when recorded
    coll_dev = rec["collectives"].get("wire_total",
                                      rec["collectives"]["total"])
    return RooflineTerms(
        compute_s=flops_dev / PEAK_FLOPS,
        memory_s=bytes_dev / HBM_BW,
        collective_s=coll_dev / ICI_BW,
        model_flops=rec["model_flops"],
        hlo_flops_total=flops_dev * chips,
        useful_ratio=(rec["model_flops"] / (flops_dev * chips))
        if flops_dev else 0.0)


def load_records(directory: str) -> list[dict]:
    recs = []
    for name in sorted(os.listdir(directory)):
        if name.endswith(".json"):
            with open(os.path.join(directory, name)) as f:
                recs.append(json.load(f))
    return recs


def format_table(recs: list[dict]) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':9s} {'pc':14s} "
           f"{'comp_ms':>8s} {'mem_ms':>8s} {'coll_ms':>8s} {'bound':>10s} "
           f"{'useful':>7s} {'roofl%':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in recs:
        t = terms_from_record(r)
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:9s} "
            f"{r['pc']:14s} "
            f"{t.compute_s*1e3:8.2f} {t.memory_s*1e3:8.2f} "
            f"{t.collective_s*1e3:8.2f} {t.dominant:>10s} "
            f"{t.useful_ratio:7.3f} {100*t.roofline_fraction:6.1f}%")
    return "\n".join(lines)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    recs = load_records(args.dir)
    print(format_table(recs))


if __name__ == "__main__":
    main()
