"""Render EXPERIMENTS.md §Dry-run + §Roofline tables from dry-run records."""
from __future__ import annotations

import argparse
import json
import os

from repro.analysis.roofline import load_records, terms_from_record


def dryrun_table(recs):
    rows = ["| arch | shape | mesh | pc | compile_s | peak_mem/dev | "
            "flops/dev | coll-wire/dev |",
            "|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        mem = r["memory"].get("peak_memory_in_bytes",
                              r["memory"].get("temp_size_in_bytes", 0))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['pc']} | "
            f"{r['compile_s']:.0f} | {mem/2**30:.2f} GiB | "
            f"{r['cost']['flops']:.2e} | "
            f"{r['collectives'].get('wire_total', 0):.2e} B |")
    return "\n".join(rows)


def roofline_table(recs):
    rows = ["| arch | shape | pc | compute_ms | memory_ms | collective_ms |"
            " bound | useful | roofline% | what moves the bound |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != "1pod":
            continue
        t = terms_from_record(r)
        hint = {
            "compute": "cut non-useful FLOPs (remat policy, masking waste)",
            "memory": "bf16 residuals / fuse elementwise / bigger blocks",
            "collective": "reduce-scatter grads, TP-stationary weights, "
                          "overlap ring",
        }[t.dominant]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['pc']} | "
            f"{t.compute_s*1e3:.2f} | {t.memory_s*1e3:.2f} | "
            f"{t.collective_s*1e3:.2f} | {t.dominant} | "
            f"{t.useful_ratio:.3f} | {100*t.roofline_fraction:.1f}% | "
            f"{hint} |")
    return "\n".join(rows)


def render(md_path: str, records_dir: str):
    recs = load_records(records_dir)
    # keep only baseline records in the main tables (no tag suffix files)
    base = [r for r in recs if r.get("param_sharding", "zero") == "zero"
            and not r.get("tag")]
    with open(md_path) as f:
        text = f.read()
    text = _replace_block(text, "DRYRUN_TABLE", dryrun_table(base))
    text = _replace_block(text, "ROOFLINE_TABLE", roofline_table(base))
    with open(md_path, "w") as f:
        f.write(text)
    print(f"rendered {len(base)} records into {md_path}")


def _replace_block(text: str, marker: str, content: str) -> str:
    tag = f"<!-- {marker} -->"
    assert tag in text, marker
    # idempotent: content lives between the marker and the next header
    start = text.index(tag) + len(tag)
    end = len(text)
    for delim in ("\n## ", "\n<!-- "):
        i = text.find(delim, start)
        if i != -1:
            end = min(end, i)
    return text[:start] + "\n\n" + content + "\n" + text[end:]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--md", default="EXPERIMENTS.md")
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    render(args.md, args.dir)
