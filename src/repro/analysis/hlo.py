"""Optimized-HLO text parsing: per-device collective byte accounting.

``compiled.cost_analysis()`` has no collective traffic, so we parse the
partitioned module: build a symbol table of every instruction's result
bytes, then for each collective op sum its *operand* sizes (the
assignment's definition of collective_bytes).  Async pairs are counted at
``-start`` only.  Tuple-shaped results (variadic collectives) and
``/*index=N*/`` comments are handled by a hand-rolled scanner — the dump
grammar is too loose for a single regex.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1,
    "token": 0, "opaque": 0, "s2": 1, "u2": 1,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute", "collective-broadcast",
                  "ragged-all-to-all")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*")
_OPERAND_RE = re.compile(r"%[\w.\-]+")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")


def _participants(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


def _wire_multiplier(op: str, n: int) -> float:
    """Ring-algorithm bytes-on-the-wire per operand byte."""
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (n - 1) / n
    if op in ("reduce-scatter", "all-to-all", "ragged-all-to-all"):
        return (n - 1) / n
    if op == "all-gather":          # operand is the local shard
        return float(n - 1)
    return 1.0                      # collective-permute / broadcast


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _balanced(text: str, start: int) -> int:
    """index just past the paren group opening at text[start] == '('."""
    depth = 0
    for i in range(start, len(text)):
        if text[i] == "(":
            depth += 1
        elif text[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(text)


def _parse_def(line: str):
    """-> (name, shape_str, op, operand_str) or None."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1).lstrip("%")
    i = m.end()
    if i >= len(line):
        return None
    # shape: either a tuple "(...)" or a single token
    if line[i] == "(":
        j = _balanced(line, i)
        shape_str = line[i:j]
    else:
        j = line.find(" ", i)
        if j < 0:
            return None
        shape_str = line[i:j]
    # op name
    while j < len(line) and line[j] == " ":
        j += 1
    k = j
    while k < len(line) and (line[k].isalnum() or line[k] in "-_."):
        k += 1
    op = line[j:k]
    if k >= len(line) or line[k] != "(":
        return name, shape_str, op, ""
    end = _balanced(line, k)
    return name, shape_str, op, line[k + 1:end - 1]


def parse_collective_bytes(hlo_text: str) -> dict:
    """{"total": int, "by_op": {op: bytes}, "counts": {op: n}} — bytes are
    per-device operand bytes (the partitioned module is per-device)."""
    sizes: dict[str, int] = {}
    defs = []
    for line in hlo_text.splitlines():
        parsed = _parse_def(line)
        if parsed is None:
            continue
        name, shape_str, op, operands = parsed
        sizes[name] = _shape_bytes(shape_str)
        defs.append((op, operands, line))

    by_op: dict[str, int] = defaultdict(int)
    wire_by_op: dict[str, float] = defaultdict(float)
    counts: dict[str, int] = defaultdict(int)
    for op, operands, line in defs:
        base = op
        for suffix in ("-start", "-done"):
            if base.endswith(suffix):
                base = base[: -len(suffix)]
        if base not in COLLECTIVE_OPS or op.endswith("-done"):
            continue
        operand_bytes = 0
        for ref in _OPERAND_RE.findall(operands):
            operand_bytes += sizes.get(ref.lstrip("%"), 0)
        by_op[base] += operand_bytes
        wire_by_op[base] += operand_bytes * _wire_multiplier(
            base, _participants(line))
        counts[base] += 1
    return {"total": int(sum(by_op.values())),
            "wire_total": int(sum(wire_by_op.values())),
            "by_op": {k: int(v) for k, v in by_op.items()},
            "wire_by_op": {k: int(v) for k, v in wire_by_op.items()},
            "counts": dict(counts)}
