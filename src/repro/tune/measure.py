"""PlanTuner stage 3: measure the top-K candidates live.

Each candidate is rebuilt as a *real* ExecutionPlan on the attached
devices (the enumeration/scoring stages never touch device state), its
train step jitted, and a few steps timed after a warmup.  Measured step
times re-rank the analytic top-K and land in the ``TunedPlan`` /
``BENCH_tune.json`` as the predicted-vs-measured record.

Candidates whose device count exceeds what is attached are skipped with
a note — measurement is an opt-in refinement, never a requirement
(the acceptance path is enumerate+score on fake devices).
"""
from __future__ import annotations

import dataclasses
import logging
import time

log = logging.getLogger("repro.tune")


def measure_plan(plan, *, steps: int = 3, warmup: int = 1) -> float:
    """Median-free simple measurement: best of ``steps`` timed jitted
    train steps (best-of is robust to host jitter at this scale)."""
    import jax
    import jax.numpy as jnp
    from repro.data.pipeline import SyntheticLM
    from repro.models.model import init_params
    from repro.train.optimizer import init_opt_state
    from repro.train.train_step import jit_train_step

    assert plan.seq_len and plan.global_batch, \
        "measurement needs the workload shape on the plan"
    data = SyntheticLM(plan.data_config(plan.seq_len, plan.global_batch),
                       plan.cfg)
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    with plan.mesh:
        params = init_params(plan.cfg, jax.random.PRNGKey(0))
        step, _, _ = jit_train_step(plan, params, donate=False)
        opt = init_opt_state(params)
        for _ in range(warmup):
            jax.block_until_ready(step(params, opt, batch))
        best = float("inf")
        for _ in range(steps):
            t0 = time.perf_counter()
            jax.block_until_ready(step(params, opt, batch))
            best = min(best, time.perf_counter() - t0)
    return best


def measure_top(cfg, result, *, k: int = 3, steps: int = 3,
                impl: str | None = None):
    """Measure the analytic top-``k`` of a ``TuneResult`` in place
    (returns the result with ``measured_s`` attached and re-ranked
    measured-first)."""
    import jax
    from repro.core.plan import build_plan

    n_dev = len(jax.devices())
    ranked = list(result.ranked)
    for i, s in enumerate(ranked[:k]):
        pc = s.cand.pc
        if pc.num_devices > n_dev:
            log.warning("skip measuring %s: needs %d devices, have %d",
                        s.tag, pc.num_devices, n_dev)
            continue
        plan = build_plan(cfg, pc, impl=impl,
                          grad_accum=s.cand.grad_accum,
                          remat=s.cand.remat, zero=s.cand.zero,
                          memory_budget_gb=result.memory_budget_gb,
                          seq_len=result.seq_len,
                          global_batch=result.global_batch)
        t = measure_plan(plan, steps=steps)
        ranked[i] = dataclasses.replace(s, measured_s=t)
        log.info("measured %s: %.1f ms (predicted %.1f ms)",
                 s.tag, t * 1e3, s.score_s * 1e3)
    # re-rank: measured candidates first by wall clock, the unmeasured
    # tail keeps its analytic order (stable sort on the bucket key)
    ranked.sort(key=lambda s: (0, s.measured_s) if s.measured_s
                is not None else (1, 0.0))
    return dataclasses.replace(result, ranked=ranked)
