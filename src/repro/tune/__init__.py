"""PlanTuner: enumerate → score → measure the 2D-Attention plan space.

The subsystem that makes the paper's §4.4 placement analysis and §4.5
performance model *executable*: given a model config, a device count and
a workload shape, it enumerates every feasible ``(dp, hp, cp_outer×w,
placement, grad_accum, remat, zero)`` point (``space.py``, pruned by the
``core/plan.py`` memory model), ranks them with the shared cost model
(``tuner.py`` over ``repro/analysis/cost.py``, constants calibrated by
``calibrate.py``), optionally measures the top-K live (``measure.py``),
and persists the winner as a ``TunedPlan`` (``cache.py``) that
``build_plan(cfg, tuned=...)`` ingests directly.

Entry points: ``python -m repro.launch.tune`` (CLI), ``tune()`` (API),
``--tune`` / ``--plan-file`` on the train/serve/dryrun launchers.
"""
from repro.tune.cache import TunedPlan                          # noqa: F401
from repro.tune.calibrate import calibrate                      # noqa: F401
from repro.tune.space import Candidate, enumerate_space         # noqa: F401
from repro.tune.tuner import (ScoredCandidate, TuneResult,      # noqa: F401
                              score_candidate, tune)
