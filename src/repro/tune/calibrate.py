"""Calibrate the §4.5 cost-model constants from on-device microbenchmarks.

Three measurements, run once and persisted (``experiments/calibration.json``
by default) so every later tuner invocation reuses them:

* **matmul** — achieved FLOP/s of a jitted ``dot`` → ``peak``;
* **copy** — achieved B/s of a jitted array copy → ``hbm``;
* **collective** — achieved B/s of a ``ppermute`` ring step over the
  local devices (the ring-attention KV hop) → ``ici``.  On a single-device
  host there is no wire to measure, so ``ici`` is rescaled by the same
  factor as the memory bandwidth — ratios between comm terms (the §4.4
  placement trade-off) are preserved exactly, and absolute predictions
  stay in the ballpark of what this host can actually execute.

The result is a :class:`repro.analysis.cost.CostConstants` whose α
factors fold the measured/nominal ratios; ``source`` records provenance
so plan files and bench JSON say which calibration scored them.
"""
from __future__ import annotations

import json
import os
import time

from repro.analysis.cost import V5E, CostConstants

CALIBRATION_VERSION = 1
DEFAULT_PATH = os.path.join("experiments", "calibration.json")


def _time_best(fn, reps: int = 5) -> float:
    """Best-of-N wall time of ``fn()`` (already warmed)."""
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run_microbenchmarks(n: int = 1024) -> dict:
    """Measure (matmul FLOP/s, copy B/s, collective B/s) on this host."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    x = jnp.asarray(np.random.default_rng(0).standard_normal((n, n)),
                    jnp.float32)
    mm = jax.jit(lambda a: a @ a)
    jax.block_until_ready(mm(x))
    t = _time_best(lambda: jax.block_until_ready(mm(x)))
    flops = 2.0 * n ** 3 / t

    big = jnp.zeros((64, n, n), jnp.float32)
    cp = jax.jit(lambda a: a + 1.0)
    jax.block_until_ready(cp(big))
    t = _time_best(lambda: jax.block_until_ready(cp(big)))
    copy_bw = 2.0 * big.size * 4 / t          # read + write

    coll_bw = None
    devs = jax.devices()
    if len(devs) > 1:
        mesh = jax.make_mesh((len(devs),), ("ring",))
        from repro.core.runtime import shard_map_compat
        from jax.sharding import PartitionSpec as P

        def hop(a):
            pairs = [(r, (r + 1) % len(devs)) for r in range(len(devs))]
            return jax.lax.ppermute(a, "ring", pairs)

        chunk = jnp.zeros((len(devs), n, n), jnp.float32)
        f = jax.jit(shard_map_compat(hop, mesh, (P("ring"),), P("ring")))
        jax.block_until_ready(f(chunk))
        t = _time_best(lambda: jax.block_until_ready(f(chunk)))
        coll_bw = n * n * 4 / t               # per-device chunk over wire
    return {"matmul_flops": flops, "copy_bw": copy_bw,
            "collective_bw": coll_bw, "n": n,
            "backend": jax.default_backend(), "devices": len(devs)}


def constants_from_raw(raw: dict) -> CostConstants:
    hbm_scale = raw["copy_bw"] / V5E.hbm
    ici = raw["collective_bw"] if raw.get("collective_bw") \
        else V5E.ici * hbm_scale
    return CostConstants(
        peak=raw["matmul_flops"], hbm=raw["copy_bw"], ici=ici,
        source=f"calibrated-{raw.get('backend', '?')}"
               f"x{raw.get('devices', 1)}")


def calibrate(path: str | None = DEFAULT_PATH, *,
              force: bool = False) -> CostConstants:
    """Load the persisted calibration, or run the microbenchmarks once
    and persist them.  ``path=None`` measures without persisting."""
    if path and not force and os.path.exists(path):
        with open(path) as f:
            saved = json.load(f)
        if saved.get("version") == CALIBRATION_VERSION:
            return constants_from_raw(saved["raw"])
    raw = run_microbenchmarks()
    if path:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump({"version": CALIBRATION_VERSION, "raw": raw},
                      f, indent=2)
    return constants_from_raw(raw)
