"""TunedPlan: the PlanTuner's serialized winner.

A ``TunedPlan`` is the complete set of knobs ``build_plan`` needs —
``(dp, hp, cp_outer×cp_inner, placement, grad_accum, remat, zero)`` plus
the workload shape it was tuned for — together with provenance (score,
measurement, calibration source, space size).  ``build_plan(cfg,
tuned=plan)`` rebuilds the exact ExecutionPlan with zero re-search, so
``launch/train.py --plan-file`` / ``launch/serve.py --plan-file`` start
from a cached tuning run.
"""
from __future__ import annotations

import dataclasses
import json
import os

from repro.core.topology import ParallelConfig

#: v2 added ``offload_chunks`` (FPDT chunk pipelining); v1 files load
#: fine — ``from_json`` filters unknown names and missing fields default.
TUNED_PLAN_VERSION = 2


@dataclasses.dataclass(frozen=True)
class TunedPlan:
    arch: str
    num_devices: int
    seq_len: int
    global_batch: int
    # parallel layout
    pods: int = 1
    dp: int = 1
    hp: int = 1
    cp_outer: int = 1
    cp_inner: int = 1
    placement: str = "head_first"
    # execution knobs
    grad_accum: int = 1
    remat: str = "scpp"            # resolved policy, never "auto"
    zero: str = "replica"          # ZERO_MODES name
    offload_chunks: int = 1        # FPDT chunk pipeline (1 = resident)
    page_size: int = 16            # serve-spec geometry that rode along
    # provenance
    predicted_s: float | None = None
    measured_s: float | None = None
    calibration: str = "v5e-nominal"
    space_size: int = 0
    version: int = TUNED_PLAN_VERSION

    def parallel(self) -> ParallelConfig:
        return ParallelConfig(dp=self.dp, hp=self.hp,
                              cp_outer=self.cp_outer,
                              cp_inner=self.cp_inner, pods=self.pods,
                              placement=self.placement)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "TunedPlan":
        d = dict(d)
        v = d.pop("version", TUNED_PLAN_VERSION)
        assert v <= TUNED_PLAN_VERSION, f"plan file from the future: v{v}"
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(version=v, **{k: x for k, x in d.items() if k in names})

    def save(self, path: str) -> str:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2)
        return path

    @classmethod
    def load(cls, path: str) -> "TunedPlan":
        with open(path) as f:
            return cls.from_json(json.load(f))
