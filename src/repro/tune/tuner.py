"""PlanTuner stages 2–3: score the enumerated space, optionally measure.

Stage 2 ranks every feasible :class:`repro.tune.space.Candidate` with the
shared §4.5 cost model (``repro/analysis/cost.py``): the score is the
modelled wall seconds of one full train step — attention (overlap model
over the hp×cp grid and Double-Ring ``w``), linear+remat recompute,
hybrid-ZeRO collectives, and grad-accum loop overhead.  DeepSpeed-Ulysses
and Megatron-CP are scored as the corners they are, so the ranking *is*
the paper's "which placement wins when" analysis, executable.

Stage 3 (optional, ``measure_top_k``) jits and times the top candidates
live (``repro/tune/measure.py``) and re-ranks them by measured step time.

``tune()`` returns a :class:`TuneResult`; ``result.tuned_plan()`` is the
serializable winner (``repro/tune/cache.py``) that ``build_plan`` ingests.
"""
from __future__ import annotations

import dataclasses

from repro.analysis.cost import (AttnCase, CostConstants, V5E,
                                 train_step_time)
from repro.tune.cache import TunedPlan
from repro.tune.space import Candidate, enumerate_space


@dataclasses.dataclass(frozen=True)
class ScoredCandidate:
    cand: Candidate
    score_s: float               # analytic step-time prediction
    terms: dict                  # train_step_time() breakdown
    measured_s: float | None = None

    @property
    def tag(self) -> str:
        return self.cand.tag


@dataclasses.dataclass
class TuneResult:
    arch: str
    num_devices: int
    seq_len: int
    global_batch: int
    memory_budget_gb: float
    const: CostConstants
    ranked: list                 # ScoredCandidate, best first
    space_size: int              # feasible points scored

    @property
    def winner(self) -> ScoredCandidate:
        assert self.ranked, "no feasible candidate"
        # measured (when present) outranks predicted
        measured = [s for s in self.ranked if s.measured_s is not None]
        if measured:
            return min(measured, key=lambda s: s.measured_s)
        return self.ranked[0]

    def tuned_plan(self, *, page_size: int = 16) -> TunedPlan:
        s = self.winner
        pc = s.cand.pc
        return TunedPlan(
            arch=self.arch, num_devices=self.num_devices,
            seq_len=self.seq_len, global_batch=self.global_batch,
            pods=pc.pods, dp=pc.dp, hp=pc.hp, cp_outer=pc.cp_outer,
            cp_inner=pc.cp_inner, placement=pc.placement,
            grad_accum=s.cand.grad_accum, remat=s.cand.remat,
            zero=s.cand.zero,
            offload_chunks=getattr(s.cand, "offload_chunks", 1),
            page_size=page_size,
            predicted_s=s.score_s, measured_s=s.measured_s,
            calibration=self.const.source, space_size=self.space_size)

    def table(self, top: int = 5) -> str:
        """The top-K candidate table (dryrun --tune prints this)."""
        hdr = (f"{'#':>2s} {'dp':>4s} {'hp':>4s} {'cp':>7s} {'pl':>2s} "
               f"{'accum':>5s} {'remat':>5s} {'zero':>7s} "
               f"{'pred_ms':>9s} {'attn_ms':>9s} {'meas_ms':>9s} "
               f"{'mem/dev':>9s}")
        lines = [f"PlanTuner: {self.arch} seq={self.seq_len} "
                 f"gb={self.global_batch} on {self.num_devices} devices "
                 f"({self.space_size} feasible points, "
                 f"const={self.const.source})", hdr, "-" * len(hdr)]
        for i, s in enumerate(self.ranked[:top]):
            pc, mem = s.cand.pc, s.cand.mem
            meas = f"{s.measured_s * 1e3:9.2f}" if s.measured_s \
                else f"{'—':>9s}"
            lines.append(
                f"{i:2d} {pc.dp:4d} {pc.hp:4d} "
                f"{pc.cp_outer:3d}x{pc.cp_inner:<3d} "
                f"{'hf' if pc.placement == 'head_first' else 'cf':>2s} "
                f"{s.cand.grad_accum:5d} {s.cand.remat:>5s} "
                f"{s.cand.zero:>7s} {s.score_s * 1e3:9.2f} "
                f"{s.terms['attn_s'] * 1e3:9.2f} {meas} "
                f"{mem['total_dev'] / 1e9:8.2f}G")
        return "\n".join(lines)


def score_candidate(cfg, cand: Candidate, *, seq_len: int,
                    global_batch: int, packing: float = 1.0,
                    const: CostConstants = V5E) -> ScoredCandidate:
    """Analytic step time of one candidate via the shared cost model.
    ``packing``: attendable causal-band fraction of a packed-document
    stream (``ExecutionPlan.packing_frac``); 1.0 = unpacked."""
    pc = cand.pc
    case = AttnCase(s=seq_len, d=cfg.d_model, h=cfg.n_heads,
                    h_kv=cfg.n_kv_heads, sp=pc.sp, hp=pc.hp,
                    w=pc.cp_inner, placement=pc.placement,
                    packing=packing,
                    offload_chunks=getattr(cand, "offload_chunks", 1))
    terms = train_step_time(
        case, d_ff=cfg.d_ff, n_layers=cfg.num_layers, remat=cand.remat,
        seqs_per_group=global_batch / (pc.pods * pc.dp),
        n_params=cand.mem["n_params"], zero_extent=cand.zero_extent,
        grad_accum=cand.grad_accum, const=const)
    return ScoredCandidate(cand=cand, score_s=terms["total_s"],
                           terms=terms)


def tune(cfg, *, num_devices: int, seq_len: int, global_batch: int,
         pods: int = 1, memory_budget_gb: float = 16.0,
         dp: int | None = None, const: CostConstants | None = None,
         measure_top_k: int = 0, measure_steps: int = 3,
         packing: float = 1.0,
         arch: str | None = None, **space_kw) -> TuneResult:
    """Enumerate → score (→ measure) the 2D-Attention plan space.

    Stage 3 runs only when ``measure_top_k > 0`` *and* the candidates fit
    the actually-attached devices; it times ``measure_steps`` jitted
    train steps per candidate (see ``repro/tune/measure.py``).
    ``packing < 1`` scores a packed-document workload (attention FLOPs
    scale down, ring/AlltoAll wire bytes do not).
    """
    const = const or V5E
    cands = enumerate_space(cfg, num_devices=num_devices, seq_len=seq_len,
                            global_batch=global_batch, pods=pods,
                            memory_budget_gb=memory_budget_gb, dp=dp,
                            **space_kw)
    scored = [score_candidate(cfg, c, seq_len=seq_len,
                              global_batch=global_batch, packing=packing,
                              const=const)
              for c in cands]
    # deterministic ranking: score, then prefer fewer moving parts
    scored.sort(key=lambda s: (s.score_s, s.cand.grad_accum,
                               s.cand.pc.hp, s.cand.pc.cp_inner,
                               s.cand.tag))
    result = TuneResult(arch=arch or cfg.name, num_devices=num_devices,
                        seq_len=seq_len, global_batch=global_batch,
                        memory_budget_gb=memory_budget_gb, const=const,
                        ranked=scored, space_size=len(scored))
    if measure_top_k > 0 and scored:
        from repro.tune.measure import measure_top
        result = measure_top(cfg, result, k=measure_top_k,
                             steps=measure_steps)
    return result
