"""PlanTuner stage 1: enumerate the joint 2D-Attention configuration space.

The degrees of freedom are the paper's §4.4/§4.5 knobs plus the execution
knobs the ExecutionPlan layer owns:

* ``(dp, hp, cp_outer, cp_inner)`` — the device split.  DeepSpeed-Ulysses
  is the ``hp == sp`` corner, Megatron-CP the ``cp == sp`` corner; the
  paper's 2D points are everything in between.  ``cp_inner`` is the
  Double-Ring ``w``.
* ``placement`` — head-first vs context-first (which sub-axis is
  ICI-minor).
* ``grad_accum`` / ``remat`` / ``zero`` — microbatching, checkpointing
  policy, hybrid-ZeRO extent.

``enumerate_space`` applies the *hard* constraints (divisibility, GQA
head replication, zigzag evenness, batch shardability) statically, then
prunes the survivors with the existing ``core/plan.py`` memory model
(``plan_memory`` — the same code ``build_plan`` runs, via its
device-free path), so no infeasible point ever reaches scoring.
"""
from __future__ import annotations

import dataclasses

from repro.core.plan import plan_memory
from repro.core.topology import ParallelConfig

#: default sweep values; ``enumerate_space`` intersects them with the
#: hard constraints of the concrete (model, devices, shape) instance.
DEFAULT_ACCUMS = (1, 2, 4, 8)
DEFAULT_REMATS = ("none", "scpp", "full")
DEFAULT_ZEROS = ("replica", "dp", "sp", "dp_sp")
DEFAULT_PLACEMENTS = ("head_first", "context_first")
MAX_INNER = 8          # paper's w sweep tops out at 8 (Table 5)
#: FPDT chunk-offload depths tried *only* when the resident point is
#: memory-infeasible — offload trades PCIe wire time for HBM, so it can
#: never beat the resident plan when the resident plan fits.
DEFAULT_OFFLOADS = (4, 8, 16)


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the search space, with its memory-model verdicts."""
    pc: ParallelConfig
    grad_accum: int
    remat: str              # resolved policy (never "auto")
    zero: str               # ZERO_MODES name
    zero_extent: int
    mem: dict               # plan_memory() output
    offload_chunks: int = 1  # FPDT chunk pipeline (1 = resident)

    @property
    def tag(self) -> str:
        p = self.pc
        base = (f"dp{p.dp}.hp{p.hp}.cp{p.cp_outer}x{p.cp_inner}."
                f"{'hf' if p.placement == 'head_first' else 'cf'}."
                f"a{self.grad_accum}.{self.remat}.{self.zero}")
        if self.offload_chunks > 1:
            base += f".off{self.offload_chunks}"
        return base


def _divisors(n: int):
    return [d for d in range(1, n + 1) if n % d == 0]


def hp_choices(cfg, sp: int):
    """hp values compatible with the attention grid: hp | sp, hp | H_q;
    below H_kv the KV heads shard over hp (needs H_kv % hp == 0), above
    it the replication path kicks in (needs hp % H_kv == 0)."""
    out = []
    for hp in _divisors(sp):
        if cfg.n_heads % hp:
            continue
        if hp > cfg.n_kv_heads:
            if hp % cfg.n_kv_heads:
                continue
        elif cfg.n_kv_heads % hp:
            continue
        out.append(hp)
    return out


def seq_ok(cfg, sp: int, cp: int, seq_len: int) -> bool:
    """S shards over all sp axes; zigzag additionally needs an even
    per-cp-rank chunk (the two half-chunks of the balanced layout)."""
    if seq_len % sp:
        return False
    if cp > 1 and cfg.zigzag and (seq_len // cp) % 2:
        return False
    return True


def chunks_ok(cfg, pc, seq_len: int, chunks: int) -> bool:
    """An FPDT chunk count is admissible when each chunk satisfies the
    same layout constraints as a full sequence: shardable over sp, and
    (under zigzag) an even per-cp-rank sub-chunk."""
    if chunks < 1 or seq_len % chunks:
        return False
    sc = seq_len // chunks
    return seq_ok(cfg, pc.sp, pc.cp, sc)


def enumerate_space(cfg, *, num_devices: int, seq_len: int,
                    global_batch: int, pods: int = 1,
                    memory_budget_gb: float = 16.0,
                    dp: int | None = None,
                    accums=DEFAULT_ACCUMS, remats=DEFAULT_REMATS,
                    zeros=DEFAULT_ZEROS, placements=DEFAULT_PLACEMENTS,
                    max_inner: int = MAX_INNER,
                    offloads=DEFAULT_OFFLOADS,
                    include_infeasible: bool = False):
    """Yield every feasible :class:`Candidate` for the instance.

    ``dp`` pins the data-parallel degree (the production frame where only
    the model axis is up for grabs); ``None`` sweeps every divisor.
    ``include_infeasible`` keeps memory-infeasible points (marked by
    ``c.mem['fits']``) for inspection; by default they are pruned.

    ZeRO modes that resolve to the same sharding extent on this mesh
    (e.g. every mode at dp=sp=1) are deduplicated, keeping the first.

    ``offloads``: FPDT chunk depths tried when (and only when) the
    resident point does not fit — the cost model then trades offload
    depth (HBM freed) against PCIe wire time among the feasible depths.
    """
    assert num_devices % pods == 0, (num_devices, pods)
    per_pod = num_devices // pods
    dps = [dp] if dp is not None else _divisors(per_pod)
    out = []
    for d in dps:
        if per_pod % d:
            continue
        sp = per_pod // d
        for hp in hp_choices(cfg, sp):
            cp = sp // hp
            if not seq_ok(cfg, sp, cp, seq_len):
                continue
            # placement is physically meaningful only on a true 2D grid:
            # with hp==1 or cp==1 the degenerate axis makes both reshapes
            # the same device order (head minor when cp==1, inner minor
            # when hp==1) — enumerate just the canonical one.
            if cp == 1:
                pls = [p for p in placements if p == "head_first"] \
                    or list(placements)[:1]
            elif hp == 1:
                pls = [p for p in placements if p == "context_first"] \
                    or list(placements)[:1]
            else:
                pls = list(placements)
            for w in _divisors(cp):
                if w > max_inner:
                    continue
                pcs = [ParallelConfig(dp=d, hp=hp, cp_outer=cp // w,
                                      cp_inner=w, pods=pods, placement=pl)
                       for pl in pls]
                for pc in pcs:
                    out.extend(_expand_exec(
                        cfg, pc, seq_len, global_batch, memory_budget_gb,
                        accums, remats, zeros, offloads,
                        include_infeasible))
    return out


def _expand_exec(cfg, pc, seq_len, global_batch, memory_budget_gb,
                 accums, remats, zeros, offloads, include_infeasible):
    out = []
    n_batch_dev = pc.pods * pc.dp
    seen_extents = set()
    for zero in zeros:
        _, _, _, probe = plan_memory(cfg, pc, zero=zero,
                                     memory_budget_gb=memory_budget_gb)
        if probe["zero_extent"] in seen_extents:
            continue              # same extent as an earlier mode: dup
        seen_extents.add(probe["zero_extent"])
        for accum in accums:
            if global_batch % accum:
                continue
            if (global_batch // accum) % n_batch_dev:
                continue          # batch must shard over (pod, data)
            for remat in remats:
                policy, zero_mode, _, mem = plan_memory(
                    cfg, pc, grad_accum=accum, remat=remat, zero=zero,
                    memory_budget_gb=memory_budget_gb,
                    seq_len=seq_len, global_batch=global_batch)
                if mem["fits"] or include_infeasible:
                    out.append(Candidate(
                        pc=pc, grad_accum=accum, remat=policy,
                        zero=zero_mode, zero_extent=mem["zero_extent"],
                        mem=mem))
                if mem["fits"] or not mem["fits_state"]:
                    # Offload frees activations only: a point whose
                    # *state* does not fit stays infeasible at any depth,
                    # and a resident-feasible point never wants offload
                    # (it would pay wire time for memory it has).
                    continue
                for chunks in offloads:
                    if not chunks_ok(cfg, pc, seq_len, chunks):
                        continue
                    policy_c, zero_c, _, mem_c = plan_memory(
                        cfg, pc, grad_accum=accum, remat=remat,
                        zero=zero, memory_budget_gb=memory_budget_gb,
                        seq_len=seq_len, global_batch=global_batch,
                        offload_chunks=chunks)
                    if not mem_c["fits"] and not include_infeasible:
                        continue
                    out.append(Candidate(
                        pc=pc, grad_accum=accum, remat=policy_c,
                        zero=zero_c, zero_extent=mem_c["zero_extent"],
                        mem=mem_c, offload_chunks=chunks))
    return out
