"""Architecture registry: ``get_config(name)`` / ``get_reduced(name)``.

One module per assigned architecture; each exposes ``config()`` (the exact
assigned dims), ``reduced()`` (a tiny same-family config for CPU smoke
tests) and ``parallel(shape, multi_pod)`` (the default 2D layout).
"""
from __future__ import annotations

import importlib

ARCHS = [
    "whisper_small",
    "zamba2_7b",
    "gemma3_12b",
    "qwen3_1_7b",
    "gemma2_2b",
    "olmo_1b",
    "qwen3_moe_30b_a3b",
    "deepseek_v2_lite_16b",
    "chameleon_34b",
    "falcon_mamba_7b",
]

#: public arch ids (dashes) -> module names
ARCH_IDS = {a.replace("_", "-"): a for a in ARCHS}
# keep the canonical ids from the assignment
CANONICAL = {
    "whisper-small": "whisper_small",
    "zamba2-7b": "zamba2_7b",
    "gemma3-12b": "gemma3_12b",
    "qwen3-1.7b": "qwen3_1_7b",
    "gemma2-2b": "gemma2_2b",
    "olmo-1b": "olmo_1b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "chameleon-34b": "chameleon_34b",
    "falcon-mamba-7b": "falcon_mamba_7b",
}


def _module(name: str):
    mod = CANONICAL.get(name) or ARCH_IDS.get(name) or name
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str):
    return _module(name).config()


def get_reduced(name: str):
    return _module(name).reduced()


def get_parallel(name: str, shape: str, multi_pod: bool = False):
    return _module(name).parallel(shape, multi_pod)


def all_arch_names():
    return list(CANONICAL.keys())
