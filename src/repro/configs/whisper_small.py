"""whisper-small [audio] enc-dec, 12L d_model=768 12H (MHA kv=12)
d_ff=3072 vocab=51865 — conv frontend STUBBED: ``input_specs`` provides
precomputed frame embeddings (B, 1536, d_model).  [arXiv:2212.04356;
unverified]

seq_len applies to the decoder/KV-cache side (config exercise — the real
model caps at 448 decoder positions); encoder context is fixed at 1536
stub frames (1500 padded to a 16-divisible length)."""
from repro.configs.common import default_parallel
from repro.models.model import ModelConfig


def config():
    return ModelConfig(
        name="whisper-small", family="encdec", num_layers=12,
        encoder_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=3072, vocab=51865, norm="ln", rope=False,
        enc_frames=1536, max_positions=32768, tie_embeddings=True)


def reduced():
    return ModelConfig(
        name="whisper-smoke", family="encdec", num_layers=2,
        encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=512, norm="ln", rope=False, enc_frames=32, max_positions=128,
        tie_embeddings=True, dtype="float32", loss_chunk=64)


def parallel(shape: str, multi_pod: bool = False):
    return default_parallel(hp=4, cp=4, multi_pod=multi_pod)
