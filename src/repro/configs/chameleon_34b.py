"""chameleon-34b [vlm] 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 — early-fusion, VQ image tokens.  [arXiv:2405.09818;
unverified]

Early fusion means image patches arrive as discrete VQ codes *inside the
token vocabulary*, so the backbone consumes plain token ids; the VQ
tokenizer is the stubbed frontend (``input_specs`` provides ids)."""
from repro.configs.common import default_parallel
from repro.models.model import ModelConfig


def config():
    return ModelConfig(
        name="chameleon-34b", family="dense", num_layers=48, d_model=8192,
        n_heads=64, n_kv_heads=8, d_ff=22016, vocab=65536,
        qk_norm=True, tie_embeddings=False, loss_chunk=4096)


def reduced():
    return ModelConfig(
        name="chameleon-34b-smoke", family="dense", num_layers=2,
        d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
        qk_norm=True, tie_embeddings=False, dtype="float32", loss_chunk=64)


def parallel(shape: str, multi_pod: bool = False):
    return default_parallel(hp=8, cp=2, multi_pod=multi_pod)
