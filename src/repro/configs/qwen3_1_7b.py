"""qwen3-1.7b [dense] 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936 — qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.common import default_parallel
from repro.models.model import ModelConfig


def config():
    return ModelConfig(
        name="qwen3-1.7b", family="dense", num_layers=28, d_model=2048,
        n_heads=16, n_kv_heads=8, d_ff=6144, vocab=151936,
        qk_norm=True, rope_theta=1e6, tie_embeddings=True,
        loss_chunk=2048)


def reduced():
    return ModelConfig(
        name="qwen3-1.7b-smoke", family="dense", num_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
        qk_norm=True, rope_theta=1e6, dtype="float32", loss_chunk=64)


def parallel(shape: str, multi_pod: bool = False):
    return default_parallel(hp=8, cp=2, multi_pod=multi_pod)
