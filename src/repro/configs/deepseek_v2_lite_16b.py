"""deepseek-v2-lite-16b [moe] 27L d_model=2048 16H d_ff=1408
vocab=102400, MoE 64e top-6 — MLA kv_lora=512, 2 shared experts.
[arXiv:2405.04434; unverified]

The assignment's primary spec (``MoE 64e top-6``) is followed; the inline
"160 routed" aside contradicts it.  All 27 layers are MoE (uniform stack —
deviation from the HF checkpoint's dense first layer, noted in DESIGN.md).
"""
from repro.configs.common import default_parallel
from repro.models.attention_block import MLADims
from repro.models.model import ModelConfig
from repro.models.moe import MoEDims


def config():
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe", num_layers=27,
        d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408, vocab=102400,
        tie_embeddings=False,
        mla=MLADims(n_heads=16, kv_lora=512, d_nope=128, d_rope=64,
                    d_v=128),
        moe=MoEDims(d_model=2048, n_experts=64, top_k=6, d_ff=1408,
                    n_shared=2, norm_topk=False))


def reduced():
    return ModelConfig(
        name="deepseek-v2-lite-smoke", family="moe", num_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=64, vocab=512,
        tie_embeddings=False, dtype="float32", loss_chunk=64,
        mla=MLADims(n_heads=4, kv_lora=32, d_nope=16, d_rope=8, d_v=16),
        moe=MoEDims(d_model=64, n_experts=8, top_k=2, d_ff=64,
                    n_shared=1, capacity_factor=8.0, norm_topk=False))


def parallel(shape: str, multi_pod: bool = False):
    return default_parallel(hp=4, cp=4, multi_pod=multi_pod)
