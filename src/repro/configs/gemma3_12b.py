"""gemma3-12b [dense] 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 — 5:1 local:global, 128k.  [hf:google/gemma-3-1b-pt;
unverified]  head_dim = d_model/H = 240 (spec-derived)."""
from repro.configs.common import default_parallel
from repro.models.model import ModelConfig


def config():
    return ModelConfig(
        name="gemma3-12b", family="dense", num_layers=48, d_model=3840,
        n_heads=16, n_kv_heads=8, d_ff=15360, vocab=262144,
        qk_norm=True, window=1024, window_pattern=6,
        rope_theta=1e6, rope_theta_local=1e4, post_norms=True,
        embed_scale=True, act="gelu", tie_embeddings=True)


def reduced():
    return ModelConfig(
        name="gemma3-12b-smoke", family="dense", num_layers=6, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
        qk_norm=True, window=16, window_pattern=6,
        rope_theta=1e6, rope_theta_local=1e4, post_norms=True,
        embed_scale=True, act="gelu", dtype="float32", loss_chunk=64)


def parallel(shape: str, multi_pod: bool = False):
    return default_parallel(hp=8, cp=2, multi_pod=multi_pod)
