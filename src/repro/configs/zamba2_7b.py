"""zamba2-7b [hybrid] 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks.
[arXiv:2411.15242; unverified]

81 Mamba2 layers; one *shared* transformer block (weights reused) runs
after every 6th Mamba layer (13 applications; 3 tail Mamba layers).
Contiguous (non-zigzag) ring attention — the SSM layers need contiguous
sequence shards (DESIGN.md §Arch-applicability)."""
from repro.configs.common import default_parallel
from repro.models.model import ModelConfig
from repro.models.ssm import Mamba2Dims


def config():
    return ModelConfig(
        name="zamba2-7b", family="hybrid", num_layers=81, d_model=3584,
        n_heads=32, n_kv_heads=32, d_ff=14336, vocab=32000,
        attn_every=6, zigzag=False, tie_embeddings=False,
        ssm2=Mamba2Dims(d_model=3584, d_inner=7168, d_state=64,
                        head_dim=64, seg=16))


def reduced():
    return ModelConfig(
        name="zamba2-smoke", family="hybrid", num_layers=7, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
        attn_every=3, zigzag=False, tie_embeddings=False, dtype="float32",
        loss_chunk=64,
        ssm2=Mamba2Dims(d_model=64, d_inner=128, d_state=8, head_dim=16,
                        seg=8))


def parallel(shape: str, multi_pod: bool = False):
    return default_parallel(hp=8, cp=2, multi_pod=multi_pod)
