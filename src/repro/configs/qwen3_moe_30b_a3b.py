"""qwen3-moe-30b-a3b [moe] 48L d_model=2048 32H (GQA kv=4) d_ff=768
vocab=151936, MoE 128e top-8.  [hf:Qwen/Qwen3-30B-A3B; hf]
head_dim = d_model/H = 64 (spec-derived)."""
from repro.configs.common import default_parallel
from repro.models.model import ModelConfig
from repro.models.moe import MoEDims


def config():
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe", num_layers=48,
        d_model=2048, n_heads=32, n_kv_heads=4, d_ff=768, vocab=151936,
        qk_norm=True, rope_theta=1e6, tie_embeddings=False,
        moe=MoEDims(d_model=2048, n_experts=128, top_k=8, d_ff=768,
                    norm_topk=True))


def reduced():
    return ModelConfig(
        name="qwen3-moe-smoke", family="moe", num_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=64, vocab=512, qk_norm=True,
        tie_embeddings=False, dtype="float32", loss_chunk=64,
        moe=MoEDims(d_model=64, n_experts=8, top_k=2, d_ff=64,
                    capacity_factor=8.0, norm_topk=True))


def parallel(shape: str, multi_pod: bool = False):
    return default_parallel(hp=4, cp=4, multi_pod=multi_pod)
