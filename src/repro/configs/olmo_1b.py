"""olmo-1b [dense] 16L d_model=2048 16H (MHA kv=16) d_ff=8192
vocab=50304 — non-parametric LN.  [arXiv:2402.00838; hf]"""
from repro.configs.common import default_parallel
from repro.models.model import ModelConfig


def config():
    return ModelConfig(
        name="olmo-1b", family="dense", num_layers=16, d_model=2048,
        n_heads=16, n_kv_heads=16, d_ff=8192, vocab=50304,
        norm="ln_np", tie_embeddings=True)


def reduced():
    return ModelConfig(
        name="olmo-1b-smoke", family="dense", num_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
        norm="ln_np", dtype="float32", loss_chunk=64)


def parallel(shape: str, multi_pod: bool = False):
    return default_parallel(hp=8, cp=2, multi_pod=multi_pod)
