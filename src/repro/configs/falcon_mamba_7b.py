"""falcon-mamba-7b [ssm] 64L d_model=4096 (attn-free) vocab=65024,
ssm_state=16 — mamba1 arch.  [arXiv:2410.05355; unverified]

2D-Attention is inapplicable (attention-free); the sequence remains sharded
over all sp axes and the selective scan crosses shards via the chunked-scan
state hand-off (see models/ssm.py + DESIGN.md §Arch-applicability)."""
from repro.configs.common import default_parallel
from repro.models.model import ModelConfig
from repro.models.ssm import Mamba1Dims


def config():
    return ModelConfig(
        name="falcon-mamba-7b", family="ssm", num_layers=64, d_model=4096,
        n_heads=1, n_kv_heads=1, d_ff=0, vocab=65024, rope=False,
        tie_embeddings=False, zigzag=False,
        ssm1=Mamba1Dims(d_model=4096, d_inner=8192, d_state=16, d_conv=4,
                        seg=16))


def reduced():
    return ModelConfig(
        name="falcon-mamba-smoke", family="ssm", num_layers=2, d_model=64,
        n_heads=1, n_kv_heads=1, d_ff=0, vocab=512, rope=False,
        tie_embeddings=False, zigzag=False, dtype="float32", loss_chunk=64,
        ssm1=Mamba1Dims(d_model=64, d_inner=128, d_state=8, d_conv=4,
                        seg=8))


def parallel(shape: str, multi_pod: bool = False):
    return default_parallel(hp=1, cp=16, inner=4, multi_pod=multi_pod,
                            placement="context_first")
