"""Shared config helpers: shapes, default parallel layouts, applicability."""
from __future__ import annotations

import dataclasses

from repro.core.topology import ParallelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str              # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

#: archs where long_500k is skipped (pure full attention — see DESIGN.md)
LONG_CTX_SKIP = {
    "qwen3-1.7b", "olmo-1b", "chameleon-34b", "whisper-small",
    "qwen3-moe-30b-a3b", "deepseek-v2-lite-16b",
}


def applicable_shapes(arch: str):
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and arch in LONG_CTX_SKIP:
            continue
        out.append(s.name)
    return out


def default_parallel(*, hp: int, cp: int, inner: int | None = None,
                     multi_pod: bool = False,
                     placement: str = "head_first") -> ParallelConfig:
    """Default layout on the production mesh: model axis (16) = hp × cp."""
    assert hp * cp == 16, (hp, cp)
    if inner is None:
        inner = min(cp, 4)
    assert cp % inner == 0
    return ParallelConfig(dp=16, hp=hp, cp_outer=cp // inner, cp_inner=inner,
                          pods=2 if multi_pod else 1, placement=placement)
