"""gemma2-2b [dense] 26L d_model=2304 8H (GQA kv=4) d_ff=9216
vocab=256000 — local+global alternating, logit softcap.
[arXiv:2408.00118; hf]"""
from repro.configs.common import default_parallel
from repro.models.model import ModelConfig


def config():
    return ModelConfig(
        name="gemma2-2b", family="dense", num_layers=26, d_model=2304,
        n_heads=8, n_kv_heads=4, d_ff=9216, vocab=256000,
        window=4096, window_pattern=2, attn_softcap=50.0,
        final_softcap=30.0, post_norms=True, embed_scale=True,
        act="gelu", tie_embeddings=True)


def reduced():
    return ModelConfig(
        name="gemma2-2b-smoke", family="dense", num_layers=4, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
        window=16, window_pattern=2, attn_softcap=50.0, final_softcap=30.0,
        post_norms=True, embed_scale=True, act="gelu", dtype="float32",
        loss_chunk=64)


def parallel(shape: str, multi_pod: bool = False):
    return default_parallel(hp=4, cp=4, multi_pod=multi_pod)
