"""Model assembly: config + init + train/prefill forward for the model zoo.

Families:
* ``dense``  — decoder-only GQA transformer (qwen3, gemma2/3, olmo,
               chameleon): optional qk-norm, logit softcaps, sliding-window/
               global layer patterns, post-norms.
* ``moe``    — dense skeleton with MoE FFN (qwen3-moe) and optionally MLA
               attention (deepseek-v2-lite).
* ``ssm``    — attention-free Mamba1 stack (falcon-mamba).
* ``hybrid`` — Mamba2 stack with a shared transformer block every
               ``attn_every`` layers (zamba2).
* ``encdec`` — Whisper: conv-frontend-stubbed encoder (non-causal 2D-Attn)
               + causal decoder with cross-attention.

Layers are grouped into *periods* (the window/global pattern length) and
scanned with ``lax.scan`` over stacked params — compile time stays flat in
depth.  Each scan body is wrapped in ``jax.checkpoint`` with the configured
policy; Selective Checkpoint++ == ``save_only_these_names("attn_out")``.

The cross-entropy is computed in token chunks inside a rematerialized scan so
the (tokens × vocab) logits never materialize (critical for gemma3's 262k
vocab at 1M-token global batches).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.runtime import shard_map_compat as _shard_map
from repro.core.runtime import Runtime
from repro.core.topology import BATCH_AXES, SEQ_AXES
from repro.models.attention_block import (AttnKind, MLADims, cross_attn_apply,
                                          gqa_apply, init_cross_attn,
                                          init_gqa, init_mla, mla_apply)
from repro.models.layers import (embedding_apply, gelu_mlp_apply,
                                 glu_mlp_apply, init_embedding, init_gelu_mlp,
                                 init_glu_mlp, init_layernorm, init_linear,
                                 init_rmsnorm, layernorm_apply,
                                 layernorm_nonparametric, linear_apply,
                                 rmsnorm_apply, rotary_cos_sin, softcap,
                                 sinusoid_positions)
from repro.models.moe import MoEDims, init_moe, moe_apply
from repro.models.ssm import (Mamba1Dims, Mamba2Dims, init_mamba1,
                              init_mamba2, mamba1_apply, mamba2_apply)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec
    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 => d_model // n_heads
    # attention flavour
    qk_norm: bool = False
    rope: bool = True
    rope_theta: float = 10000.0
    rope_theta_local: float = 10000.0
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    window: int | None = None
    window_pattern: int = 0      # period p: layer i is global iff i%p==p-1
    attn_bias: bool = False
    post_norms: bool = False     # gemma2/3 post-block norms
    # norms / mlp
    norm: str = "rms"            # rms | ln | ln_np
    act: str = "silu"
    # embeddings
    embed_scale: bool = False    # gemma: x *= sqrt(d_model)
    tie_embeddings: bool = True
    # family extras
    moe: MoEDims | None = None
    mla: MLADims | None = None
    ssm1: Mamba1Dims | None = None
    ssm2: Mamba2Dims | None = None
    attn_every: int = 0          # hybrid: shared attn block period
    encoder_layers: int = 0
    enc_frames: int = 1536       # stub conv-frontend output length (padded)
    max_positions: int = 4096    # whisper learned decoder positions
    # execution
    dtype: str = "bfloat16"
    remat: str = "scpp"          # none | full | scpp
    zigzag: bool = True
    loss_chunk: int = 512
    init_std: float = 0.02
    #: python-unroll every layer/chunk loop.  Dry-runs set this: XLA's
    #: cost_analysis counts a while body ONCE, so looped lowering would
    #: undercount FLOPs/collective-bytes by ~num_layers.
    unroll_loops: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    def attn_kind(self, layer_in_period: int) -> AttnKind:
        """Attention kind for position ``layer_in_period`` of the pattern."""
        if self.window is not None and self.window_pattern:
            is_global = layer_in_period % self.window_pattern == \
                self.window_pattern - 1
        else:
            is_global = True
        return AttnKind(
            causal=True,
            window=None if is_global else self.window,
            softcap=self.attn_softcap,
            rope=self.rope,
            rope_theta=self.rope_theta if is_global
            else self.rope_theta_local)

    @property
    def period(self) -> int:
        if self.family in ("dense", "moe"):
            return self.window_pattern or 1
        return 1


def remat_policy(name: str):
    if name == "none":
        return "none"
    if name == "full":
        return jax.checkpoint_policies.nothing_saveable
    if name == "scpp":
        return jax.checkpoint_policies.save_only_these_names("attn_out")
    raise ValueError(name)


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def maybe_scan(body, init, xs, unroll: bool):
    """lax.scan, or a python-unrolled equivalent (for dry-run costing)."""
    if not unroll:
        return lax.scan(body, init, xs)
    carry = init
    ys = []
    n = jax.tree.leaves(xs)[0].shape[0]
    for i in range(n):
        x_i = jax.tree.map(lambda t: t[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *t: jnp.stack(t), *ys)
    else:
        ys = None
    return carry, ys


# ---------------------------------------------------------------------------
# Norm helpers
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, dim: int):
    if cfg.norm == "rms":
        return init_rmsnorm(dim)
    if cfg.norm == "ln":
        return init_layernorm(dim)
    if cfg.norm == "ln_np":
        return {}
    raise ValueError(cfg.norm)


def apply_norm(cfg: ModelConfig, p, x):
    if cfg.norm == "rms":
        return rmsnorm_apply(p, x)
    if cfg.norm == "ln":
        return layernorm_apply(p, x)
    return layernorm_nonparametric(x)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def init_transformer_block(key, cfg: ModelConfig, *, moe_layer: bool):
    ks = jax.random.split(key, 4)
    p = {"ln1": init_norm(cfg, cfg.d_model),
         "ln2": init_norm(cfg, cfg.d_model)}
    if cfg.mla is not None:
        p["attn"] = init_mla(ks[0], cfg.d_model, cfg.mla)
    else:
        p["attn"] = init_gqa(ks[0], cfg.d_model, cfg.n_heads,
                             cfg.n_kv_heads, cfg.hd, qk_norm=cfg.qk_norm,
                             bias=cfg.attn_bias)
    if moe_layer:
        p["moe"] = init_moe(ks[1], cfg.moe)
    else:
        p["mlp"] = init_glu_mlp(ks[1], cfg.d_model, cfg.d_ff)
    if cfg.post_norms:
        p["pn1"] = init_norm(cfg, cfg.d_model)
        p["pn2"] = init_norm(cfg, cfg.d_model)
    return p


def apply_transformer_block(p, x, ropes, rt: Runtime, cfg: ModelConfig,
                            kind: AttnKind, *, moe_layer: bool,
                            doc_start=None):
    """Returns (x, aux_loss)."""
    cos, sin = ropes[kind.rope_theta]
    h = apply_norm(cfg, p["ln1"], x)
    if cfg.mla is not None:
        h = mla_apply(p["attn"], h, cos, sin, rt, kind, cfg.mla,
                      zigzag=cfg.zigzag, doc_start=doc_start)
    else:
        h = gqa_apply(p["attn"], h, cos, sin, rt, kind,
                      n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                      head_dim=cfg.hd, qk_norm=cfg.qk_norm,
                      zigzag=cfg.zigzag, doc_start=doc_start)
    if cfg.post_norms:
        h = apply_norm(cfg, p["pn1"], h)
    x = x + h
    h = apply_norm(cfg, p["ln2"], x)
    aux = jnp.zeros((), jnp.float32)
    if moe_layer:
        h, aux = moe_apply(p["moe"], h, rt, cfg.moe)
    else:
        h = glu_mlp_apply(p["mlp"], h, act=cfg.act)
    if cfg.post_norms:
        h = apply_norm(cfg, p["pn2"], h)
    return x + h, aux


def init_mamba_block(key, cfg: ModelConfig, kind: str):
    p = {"ln": init_norm(cfg, cfg.d_model)}
    if kind == "mamba1":
        p["mix"] = init_mamba1(key, cfg.ssm1)
    else:
        p["mix"] = init_mamba2(key, cfg.ssm2)
    return p


def apply_mamba_block(p, x, rt: Runtime, cfg: ModelConfig, kind: str):
    h = apply_norm(cfg, p["ln"], x)
    if kind == "mamba1":
        h = mamba1_apply(p["mix"], h, rt, cfg.ssm1)
    else:
        h = mamba2_apply(p["mix"], h, rt, cfg.ssm2)
    return x + h


# ---------------------------------------------------------------------------
# Rope table
# ---------------------------------------------------------------------------

def build_ropes(cfg: ModelConfig, positions):
    """{theta: (cos, sin)} for every theta the layer pattern uses."""
    thetas = {cfg.rope_theta}
    if cfg.window is not None and cfg.window_pattern:
        thetas.add(cfg.rope_theta_local)
    dt = cfg.compute_dtype
    return {th: rotary_cos_sin(positions, cfg.hd if cfg.mla is None
                               else cfg.mla.d_rope, theta=th, dtype=dt)
            for th in sorted(thetas)}


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> dict:
    ks = iter(jax.random.split(key, 4 * cfg.num_layers + 64))
    params: dict[str, Any] = {
        "embed": init_embedding(next(ks), cfg.vocab, cfg.d_model,
                                std=cfg.init_std)}
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(next(ks), cfg.d_model, cfg.vocab,
                                        std=cfg.init_std)
    params["final_norm"] = init_norm(cfg, cfg.d_model)

    if cfg.family in ("dense", "moe"):
        period = cfg.period
        n_groups = cfg.num_layers // period
        assert cfg.num_layers % period == 0, (cfg.num_layers, period)
        groups = []
        for _ in range(n_groups):
            groups.append([init_transformer_block(
                next(ks), cfg, moe_layer=cfg.family == "moe")
                for _ in range(period)])
        # stack: list over period slots, each stacked over groups
        params["blocks"] = [_stack([g[slot] for g in groups])
                            for slot in range(period)]
    elif cfg.family == "ssm":
        params["blocks"] = _stack([init_mamba_block(next(ks), cfg, "mamba1")
                                   for _ in range(cfg.num_layers)])
    elif cfg.family == "hybrid":
        period = cfg.attn_every
        n_groups = cfg.num_layers // period
        rem = cfg.num_layers - n_groups * period
        params["blocks"] = _stack(
            [_stack([init_mamba_block(next(ks), cfg, "mamba2")
                     for _ in range(period)]) for _ in range(n_groups)])
        if rem:
            params["blocks_tail"] = _stack(
                [init_mamba_block(next(ks), cfg, "mamba2")
                 for _ in range(rem)])
        params["shared_attn"] = init_transformer_block(next(ks), cfg,
                                                       moe_layer=False)
    elif cfg.family == "encdec":
        params["enc_blocks"] = _stack(
            [init_whisper_block(next(ks), cfg, cross=False)
             for _ in range(cfg.encoder_layers)])
        params["dec_blocks"] = _stack(
            [init_whisper_block(next(ks), cfg, cross=True)
             for _ in range(cfg.num_layers)])
        params["enc_norm"] = init_norm(cfg, cfg.d_model)
        params["dec_pos"] = init_embedding(next(ks), cfg.max_positions,
                                           cfg.d_model, std=cfg.init_std)
    else:
        raise ValueError(cfg.family)
    return params


def init_whisper_block(key, cfg: ModelConfig, *, cross: bool):
    ks = jax.random.split(key, 3)
    p = {"ln1": init_norm(cfg, cfg.d_model),
         "attn": init_gqa(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                          cfg.hd, bias=True),
         "ln2": init_norm(cfg, cfg.d_model),
         "mlp": init_gelu_mlp(ks[1], cfg.d_model, cfg.d_ff)}
    if cross:
        p["lnx"] = init_norm(cfg, cfg.d_model)
        p["cross"] = init_cross_attn(ks[2], cfg.d_model, cfg.n_heads, cfg.hd)
    return p


# ---------------------------------------------------------------------------
# Forward (training / prefill trunk)
# ---------------------------------------------------------------------------

def _scan_blocks(body, x, stacked, policy, collect: bool = False,
                 unroll: bool = False):
    """scan with per-step remat.  body(x, layer_params) -> (x, aux[, ys])."""
    if policy == "none":
        wrapped = body
    else:
        wrapped = jax.checkpoint(body, policy=policy, prevent_cse=False)

    def step(carry, lp):
        x, aux = carry
        out = wrapped(x, lp)
        if collect:
            x, a, ys = out
            return (x, aux + a), ys
        x, a = out
        return (x, aux + a), None

    (x, aux), ys = maybe_scan(step, (x, jnp.zeros((), jnp.float32)),
                              stacked, unroll)
    return x, aux, ys


def backbone(params, x, ropes, rt: Runtime, cfg: ModelConfig,
             doc_start=None):
    """Embedded input -> final hidden states.  Returns (x, aux).

    ``doc_start`` (packed documents) reaches only the attention blocks;
    SSM mixing layers are sequence-recurrent and have no packed mode
    (their state would need per-document resets) — packing is gated to
    attention families by the ExecutionPlan.
    """
    aux_total = jnp.zeros((), jnp.float32)
    policy = remat_policy(cfg.remat)

    if cfg.family in ("dense", "moe"):
        period = cfg.period
        kinds = [cfg.attn_kind(i) for i in range(period)]

        def body(x, lps):
            aux = jnp.zeros((), jnp.float32)
            for slot in range(period):
                x, a = apply_transformer_block(
                    lps[slot], x, ropes, rt, cfg, kinds[slot],
                    moe_layer=cfg.family == "moe", doc_start=doc_start)
                aux = aux + a
            return x, aux

        x, aux_total, _ = _scan_blocks(body, x, params["blocks"], policy,
                                       unroll=cfg.unroll_loops)

    elif cfg.family == "ssm":
        def body(x, lp):
            return apply_mamba_block(lp, x, rt, cfg, "mamba1"), \
                jnp.zeros((), jnp.float32)
        x, aux_total, _ = _scan_blocks(body, x, params["blocks"], policy,
                                       unroll=cfg.unroll_loops)

    elif cfg.family == "hybrid":
        shared = params["shared_attn"]
        kind = cfg.attn_kind(0)

        def body(x, lps):
            for i in range(cfg.attn_every):
                x = apply_mamba_block(
                    jax.tree.map(lambda t: t[i], lps), x, rt, cfg, "mamba2")
            x, a = apply_transformer_block(shared, x, ropes, rt, cfg, kind,
                                           moe_layer=False)
            return x, a

        x, aux_total, _ = _scan_blocks(body, x, params["blocks"], policy,
                                       unroll=cfg.unroll_loops)
        if "blocks_tail" in params:
            def tail(x, lp):
                return apply_mamba_block(lp, x, rt, cfg, "mamba2"), \
                    jnp.zeros((), jnp.float32)
            x, _, _ = _scan_blocks(tail, x, params["blocks_tail"], policy,
                                   unroll=cfg.unroll_loops)
    else:
        raise ValueError(cfg.family)
    return x, aux_total


def whisper_encoder(params, frames, rt: Runtime, cfg: ModelConfig):
    """frames: (B, T_enc, D) stubbed conv-frontend output."""
    dt = cfg.compute_dtype
    x = frames.astype(dt) + sinusoid_positions(frames.shape[1], cfg.d_model,
                                               dt)[None]
    policy = remat_policy(cfg.remat)
    kind = AttnKind(causal=False, rope=False)

    def body(x, lp):
        h = apply_norm(cfg, lp["ln1"], x)
        h = gqa_apply(lp["attn"], h, None, None, rt, kind,
                      n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                      head_dim=cfg.hd, zigzag=False)
        x = x + h
        h = gelu_mlp_apply(lp["mlp"], apply_norm(cfg, lp["ln2"], x))
        return x + h, jnp.zeros((), jnp.float32)

    x, _, _ = _scan_blocks(body, x, params["enc_blocks"], policy,
                           unroll=cfg.unroll_loops)
    return apply_norm(cfg, params["enc_norm"], x)


def whisper_decoder(params, x, enc_out, ropes, rt: Runtime,
                    cfg: ModelConfig, positions):
    policy = remat_policy(cfg.remat)
    kind = AttnKind(causal=True, rope=False)
    x = x + embedding_apply(params["dec_pos"],
                            jnp.minimum(positions, cfg.max_positions - 1),
                            dtype=x.dtype)

    def body(x, lp):
        h = apply_norm(cfg, lp["ln1"], x)
        h = gqa_apply(lp["attn"], h, None, None, rt, kind,
                      n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                      head_dim=cfg.hd, zigzag=cfg.zigzag)
        x = x + h
        h = cross_attn_apply(lp["cross"], apply_norm(cfg, lp["lnx"], x),
                             enc_out, rt, n_heads=cfg.n_heads,
                             head_dim=cfg.hd)
        x = x + h
        h = gelu_mlp_apply(lp["mlp"], apply_norm(cfg, lp["ln2"], x))
        return x + h, jnp.zeros((), jnp.float32)

    x, _, _ = _scan_blocks(body, x, params["dec_blocks"], policy,
                           unroll=cfg.unroll_loops)
    return x


# ---------------------------------------------------------------------------
# Loss (chunked, never materializes tokens × vocab)
# ---------------------------------------------------------------------------

def chunked_xent(x, w_head, labels, rt: Runtime, cfg: ModelConfig):
    """x: (B, S, D); w_head: (D, V); labels: (B, S) int32 (-1 = pad).

    Returns (loss_sum, n_valid) — both replicated scalars.
    """
    cap = cfg.final_softcap

    def local(x, w, labels):
        b_loc, s_loc, d = x.shape
        t = b_loc * s_loc
        chunk = min(cfg.loss_chunk, t)
        while t % chunk:
            chunk -= 1
        xt = x.reshape(t, d)
        lt = labels.reshape(t)

        def chunk_fn(carry, xs):
            xc, lc = xs
            logits = (xc @ w.astype(xc.dtype)).astype(jnp.float32)
            if cap:
                logits = softcap(logits, cap)
            lse = jax.nn.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(
                logits, jnp.maximum(lc, 0)[:, None], axis=1)[:, 0]
            valid = (lc >= 0)
            loss = jnp.where(valid, lse - ll, 0.0)
            # (1,)-shaped carries: 0-d values saved as shard_map grad
            # residuals crash legacy shard_map's partial eval (its scalar-
            # residual promotion misses them), so keep a singleton axis.
            return (carry[0] + loss.sum(keepdims=True),
                    carry[1] + valid.sum(keepdims=True).astype(jnp.float32)
                    ), None

        xs = (xt.reshape(t // chunk, chunk, d),
              lt.reshape(t // chunk, chunk))
        (loss_sum, n_valid), _ = maybe_scan(
            jax.checkpoint(chunk_fn), (jnp.zeros((1,), jnp.float32),
                                       jnp.zeros((1,), jnp.float32)), xs,
            cfg.unroll_loops)
        loss_sum = lax.psum(loss_sum[0], BATCH_AXES + SEQ_AXES)
        n_valid = lax.psum(n_valid[0], BATCH_AXES + SEQ_AXES)
        return loss_sum, n_valid

    spec_x = P(BATCH_AXES, SEQ_AXES, None)
    spec_l = P(BATCH_AXES, SEQ_AXES)
    f = _shard_map(local, rt.mesh, (spec_x, P(None, None), spec_l),
                   (P(), P()))
    return f(x, w_head, labels)


def cast_params_once(params, cfg: ModelConfig):
    """Cast matrix params to the compute dtype *once*, before any use.

    Without this, XLA gathers ZeRO-sharded fp32 masters and converts after
    the all-gather — 2× the gather wire bytes.  A single up-front convert
    keeps every gather in bf16 (numerics identical: the same cast happened
    per-use before).  Precision-critical leaves (A_log: exp() of it drives
    SSM decay) stay fp32.
    """
    dt = cfg.compute_dtype
    if dt == jnp.float32:
        return params

    def cast(path, x):
        name = jax.tree_util.keystr(path)
        if "A_log" in name or x.ndim < 2 or \
                not jnp.issubdtype(x.dtype, jnp.floating):
            return x
        return x.astype(dt)

    return jax.tree_util.tree_map_with_path(cast, params)


def lm_head_weight(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"]["table"].T
    return params["lm_head"]["w"]


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def embed_tokens(params, tokens, cfg: ModelConfig):
    scale = cfg.d_model ** 0.5 if cfg.embed_scale else None
    return embedding_apply(params["embed"], tokens,
                           dtype=cfg.compute_dtype, scale=scale)


def forward_loss(params, batch, rt: Runtime, cfg: ModelConfig):
    """batch: {tokens, labels, positions[, frames]} -> (loss, metrics)."""
    tokens = batch["tokens"]
    positions = batch["positions"]
    doc_start = batch.get("doc_start")       # packed documents (PackedLM)
    params = cast_params_once(params, cfg)
    x = embed_tokens(params, tokens, cfg)
    x = rt.constrain(x, None)
    ropes = build_ropes(cfg, positions) if cfg.rope else {}

    if cfg.family == "encdec":
        enc = whisper_encoder(params, batch["frames"], rt, cfg)
        x = whisper_decoder(params, x, enc, ropes, rt, cfg, positions)
        aux = jnp.zeros((), jnp.float32)
    else:
        x, aux = backbone(params, x, ropes, rt, cfg, doc_start=doc_start)

    x = apply_norm(cfg, params["final_norm"], x)
    x = rt.constrain(x, None)
    loss_sum, n_valid = chunked_xent(x, lm_head_weight(params, cfg),
                                     batch["labels"], rt, cfg)
    loss = loss_sum / jnp.maximum(n_valid, 1.0) + aux
    return loss, {"loss": loss, "xent": loss_sum / jnp.maximum(n_valid, 1.0),
                  "aux": aux, "n_tokens": n_valid}
