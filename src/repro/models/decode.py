"""Serving: cache construction, prefill, and single-token decode.

* **Attention decode** is flash-decoding on the 2D grid: the KV cache is
  sharded over the context axes (S) and the head axis (KV heads); each
  context rank computes a partial against its shard, one pmax+psum combines
  (``attention_block.decode_attention``).
* **Sliding-window layers** use ring-buffer caches of size ``window`` —
  without this, gemma3's 40 local layers at 500k context would need TBs.
* **MLA decode** runs *absorbed*: the cache stores the compressed latent
  (kv_lora + rope = 576/token instead of materialized 16×2×192 = 6144), and
  the per-head up-projections are folded into q / out — a beyond-paper
  communication/memory win recorded in DESIGN.md.
* **SSM decode** is the O(1)-state recurrence (``ssm.mamba*_decode``).
* Prefill reuses the training forward in *contiguous* (non-zigzag) ring mode
  so collected caches are in natural sequence order.
* **Paged decode** (``PagedLayout``): full-attention K/V (and the MLA
  latent) live in fixed-size block pools; ``decode_step`` scatters the new
  token through per-request block tables and gathers a contiguous view for
  the flash-decoding combine.  ``pos`` may be a per-request ``(B,)`` vector
  (ragged continuous batching); sliding-window layers keep their ring
  buffers (already O(window)) in both modes.  ``prefill_chunk`` is the
  chunked-prefill building block of the serve engine.

Caches mirror the stacked-params structure so decode scans over layers.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.runtime import Runtime
from repro.core.topology import (AXIS_HP, AXIS_INNER, AXIS_OUTER, BATCH_AXES,
                                 MODEL_AXES)
from repro.models.attention_block import (AttnKind, decode_attention,
                                          MLADims)
from repro.models.layers import (apply_rotary, embedding_apply,
                                 gelu_mlp_apply, glu_mlp_apply, linear_apply,
                                 rmsnorm_apply, rotary_cos_sin,
                                 sinusoid_positions)
from repro.models.model import (ModelConfig, apply_norm, build_ropes,
                                cast_params_once, embed_tokens,
                                lm_head_weight, maybe_scan)
from repro.models.moe import moe_apply
from repro.models.ssm import mamba1_decode, mamba2_decode
from repro.kernels.ops import flash_attention, flash_fwd_chunk


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------

def kv_cache_spec(batch_axes=BATCH_AXES):
    """PartitionSpec of a (layers, B, S, H, d) stacked KV cache."""
    return P(None, batch_axes, (AXIS_OUTER, AXIS_INNER), AXIS_HP, None)


def _kv_shape(cfg: ModelConfig, b: int, s: int, *, window: int | None):
    s_eff = min(s, window) if window is not None else s
    return (b, s_eff, cfg.n_kv_heads, cfg.hd)


def init_caches(cfg: ModelConfig, b: int, s_max: int):
    """Zero caches (host shapes; the dry-run passes ShapeDtypeStructs)."""
    dt = cfg.compute_dtype
    if cfg.family in ("dense", "moe"):
        if cfg.mla is not None:
            m = cfg.mla
            n = cfg.num_layers
            return {"blocks": [{
                "c": jnp.zeros((n, b, s_max, m.kv_lora), dt),
                "rope": jnp.zeros((n, b, s_max, m.d_rope), dt)}]}
        period = cfg.period
        groups = cfg.num_layers // period
        caches = []
        for slot in range(period):
            kind = cfg.attn_kind(slot)
            shp = _kv_shape(cfg, b, s_max, window=kind.window)
            caches.append({"k": jnp.zeros((groups,) + shp, dt),
                           "v": jnp.zeros((groups,) + shp, dt)})
        return {"blocks": caches}
    if cfg.family == "ssm":
        m = cfg.ssm1
        n = cfg.num_layers
        return {"blocks": {
            "h": jnp.zeros((n, b, m.d_inner, m.d_state), jnp.float32),
            "conv": jnp.zeros((n, b, m.d_conv - 1, m.d_inner), dt)}}
    if cfg.family == "hybrid":
        m = cfg.ssm2
        groups = cfg.num_layers // cfg.attn_every
        rem = cfg.num_layers - groups * cfg.attn_every
        shp = _kv_shape(cfg, b, s_max, window=None)
        caches = {"blocks": {
            "h": jnp.zeros((groups, cfg.attn_every, b, m.n_heads,
                            m.head_dim, m.d_state), jnp.float32),
            "conv": jnp.zeros((groups, cfg.attn_every, b, m.d_conv - 1,
                               m.conv_dim), dt)},
            "shared_attn": {"k": jnp.zeros((groups,) + shp, dt),
                            "v": jnp.zeros((groups,) + shp, dt)}}
        if rem:
            caches["blocks_tail"] = {
                "h": jnp.zeros((rem, b, m.n_heads, m.head_dim, m.d_state),
                               jnp.float32),
                "conv": jnp.zeros((rem, b, m.d_conv - 1, m.conv_dim), dt)}
        return caches
    if cfg.family == "encdec":
        n = cfg.num_layers
        shp = _kv_shape(cfg, b, s_max, window=None)
        enc_shp = (b, cfg.enc_frames, cfg.n_heads, cfg.hd)
        return {"dec_blocks": {"k": jnp.zeros((n,) + shp, dt),
                               "v": jnp.zeros((n,) + shp, dt)},
                "cross": {"k": jnp.zeros((n,) + enc_shp, dt),
                          "v": jnp.zeros((n,) + enc_shp, dt)}}
    raise ValueError(cfg.family)


def grow_caches(cfg: ModelConfig, caches, extra: int):
    """Pad attention caches with ``extra`` free positions along S so decode
    can write past the prefill length (SSM states and full ring buffers are
    size-invariant).  Sliding-window buffers are padded up to ``window``
    when the prompt was shorter than the window.

    Ring-buffer slot math assumes ``window | S_prefill`` when the prompt
    exceeds the window (true for all assigned configs: 1024/4096 | 32k/512k).
    """
    def pad_s(x, target_extra, axis=2):
        pads = [(0, 0)] * x.ndim
        pads[axis] = (0, target_extra)
        return jnp.pad(x, pads)

    out = dict(caches)
    if cfg.family in ("dense", "moe"):
        if cfg.mla is not None:
            blk = caches["blocks"][0]
            out["blocks"] = [{k: pad_s(v, extra) for k, v in blk.items()}]
            return out
        new_slots = []
        for slot, blk in enumerate(caches["blocks"]):
            kind = cfg.attn_kind(slot)
            if kind.window is None:
                new_slots.append({k: pad_s(v, extra) for k, v in
                                  blk.items()})
            else:
                cur = blk["k"].shape[2]
                grow = max(0, min(kind.window, cur + extra) - cur)
                new_slots.append({k: pad_s(v, grow) for k, v in
                                  blk.items()})
        out["blocks"] = new_slots
        return out
    if cfg.family == "hybrid":
        out["shared_attn"] = {k: pad_s(v, extra) for k, v in
                              caches["shared_attn"].items()}
        return out
    if cfg.family == "encdec":
        out["dec_blocks"] = {k: pad_s(v, extra) for k, v in
                             caches["dec_blocks"].items()}
        return out
    return out     # ssm: state-only


def cache_shardings(cfg: ModelConfig, caches, mesh, batch_axes=BATCH_AXES):
    """NamedSharding pytree matching init_caches output."""
    def spec_for(path: str, x):
        leaf = path.split("/")[-1]
        if leaf in ("k", "v") and x.ndim == 5:   # KV cache (L,B,S,H,d)
            return kv_cache_spec(batch_axes)
        if leaf == "h":
            if x.ndim == 6:   # hybrid ssm state (G,p,B,nh,hd,N)
                return P(None, None, batch_axes, MODEL_AXES, None, None)
            return P(None, batch_axes, MODEL_AXES, None)  # (L,B,di,N)
        if leaf == "conv":
            if x.ndim == 5:   # hybrid conv tail (G,p,B,K-1,convd)
                return P(None, None, batch_axes, None, MODEL_AXES)
            return P(None, batch_axes, None, MODEL_AXES)  # (L,B,K-1,di)
        if leaf in ("c", "rope"):         # MLA latent (L,B,S,lora)
            return P(None, batch_axes, (AXIS_OUTER, AXIS_INNER), None)
        return P(None, batch_axes) if x.ndim == 2 else \
            P(None, batch_axes, *([None] * (x.ndim - 2)))

    def walk(tree, path=""):
        if isinstance(tree, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in tree.items()}
        if isinstance(tree, list):
            return [walk(v, f"{path}/{i}") for i, v in enumerate(tree)]
        return NamedSharding(mesh, spec_for(path, tree))

    return walk(caches)


# ---------------------------------------------------------------------------
# Paged-KV layout: block pools + per-request block tables
# ---------------------------------------------------------------------------

class PagedLayout(NamedTuple):
    """How a paged cache pool maps logical positions to physical blocks.

    Pools are ``(num_blocks, page_size, ...)`` (per layer; stacked pools
    carry a leading layer/group dim).  ``block_tables[b, i]`` is the
    physical block holding request ``b``'s logical positions
    ``[i*page_size, (i+1)*page_size)``; tables are shared across layers
    (every layer's pool uses the same geometry).  Writes for inactive
    slots (``pos < 0``) are routed out of bounds and dropped, so a shared
    physical block is never corrupted by a retired request.
    """
    block_tables: jax.Array        # (B, max_blocks_per_seq) int32
    page_size: int                 # static
    num_blocks: int                # static — pool extent, drop bound


def _paged_write(pool, vals, pos, paged: PagedLayout):
    """Scatter one token per request: pool (NB,Pg,...), vals (B,...),
    pos scalar/(B,) logical positions (< 0 → dropped)."""
    b = paged.block_tables.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    blk = jnp.clip(pos // paged.page_size, 0,
                   paged.block_tables.shape[1] - 1)
    phys = jnp.take_along_axis(paged.block_tables, blk[:, None],
                               axis=1)[:, 0]
    phys = jnp.where(pos >= 0, phys, paged.num_blocks)   # OOB → dropped
    return pool.at[phys, pos % paged.page_size].set(
        vals.astype(pool.dtype), mode="drop")


def _paged_write_chunk(pool, vals, start, valid, paged: PagedLayout):
    """Scatter a prefill chunk: vals (B,Lc,...), positions
    start..start+valid per request (rows ≥ valid dropped)."""
    b, lc = vals.shape[:2]
    t = jnp.arange(lc, dtype=jnp.int32)[None]
    pos = jnp.broadcast_to(jnp.asarray(start, jnp.int32).reshape(-1, 1),
                           (b, 1)) + t                   # (B, Lc)
    live = t < jnp.asarray(valid, jnp.int32).reshape(-1, 1)
    blk = jnp.clip(pos // paged.page_size, 0,
                   paged.block_tables.shape[1] - 1)
    phys = jnp.take_along_axis(paged.block_tables, blk, axis=1)
    phys = jnp.where(live, phys, paged.num_blocks)       # OOB → dropped
    return pool.at[phys, pos % paged.page_size].set(
        vals.astype(pool.dtype), mode="drop")


def _paged_view(pool, paged: PagedLayout):
    """(NB,Pg,...) -> (B, max_blocks*Pg, ...) gathered through the block
    tables — the contiguous view the flash-decoding combine attends."""
    pages = pool[paged.block_tables]          # (B, MAXB, Pg, ...)
    b, nb, pg = pages.shape[:3]
    return pages.reshape((b, nb * pg) + pages.shape[3:])


# ---------------------------------------------------------------------------
# Per-layer decode helpers
# ---------------------------------------------------------------------------

def _ring_pos_write(cache, new, write):
    """cache (B,S,...), new (B,1,...), write scalar/(B,) slot indices."""
    new = new.astype(cache.dtype)
    write = jnp.asarray(write, jnp.int32)
    if write.ndim:
        return jax.vmap(
            lambda c, n, p: lax.dynamic_update_slice_in_dim(c, n, p, axis=0)
        )(cache, new, jnp.maximum(write, 0))
    return lax.dynamic_update_slice_in_dim(cache, new, write, axis=1)


def _update_cache(cache, new, pos, *, window: int | None,
                  paged: PagedLayout | None = None):
    """cache (B,S,H,d) contiguous / (B,W,H,d) ring / (NB,Pg,H,d) paged
    pool; new (B,1,H,d); pos scalar or per-request (B,).  Ring-buffered
    for window layers (both modes — windows are already O(window))."""
    pos = jnp.asarray(pos, jnp.int32)
    if window is not None:
        return _ring_pos_write(cache, new, pos % cache.shape[1])
    if paged is not None:
        return _paged_write(cache, new[:, 0], pos, paged)
    return _ring_pos_write(cache, new, pos)


def _gqa_decode(p, x, cache, pos, rt, cfg: ModelConfig, kind: AttnKind,
                ropes, paged: PagedLayout | None = None):
    b = x.shape[0]
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = linear_apply(p["wq"], x).reshape(b, 1, h, hd)
    k = linear_apply(p["wk"], x).reshape(b, 1, hkv, hd)
    v = linear_apply(p["wv"], x).reshape(b, 1, hkv, hd)
    if cfg.qk_norm:
        q = rmsnorm_apply(p["qn"], q)
        k = rmsnorm_apply(p["kn"], k)
    if kind.rope:
        cos, sin = ropes[kind.rope_theta]
        q = apply_rotary(q, cos, sin)
        k = apply_rotary(k, cos, sin)
    use_paged = paged if kind.window is None else None
    k_cache = _update_cache(cache["k"], k, pos, window=kind.window,
                            paged=use_paged)
    v_cache = _update_cache(cache["v"], v, pos, window=kind.window,
                            paged=use_paged)
    if kind.window is not None:
        # Ring buffer: every live slot is inside the window — plain valid-
        # length masking, handled as full attention over min(pos+1, W) keys.
        out = decode_attention(q, k_cache, v_cache,
                               jnp.minimum(pos, k_cache.shape[1] - 1), rt,
                               softcap=kind.softcap, window=None,
                               ring_full=jnp.minimum(pos + 1,
                                                     k_cache.shape[1]))
    else:
        k_att = _paged_view(k_cache, paged) if use_paged else k_cache
        v_att = _paged_view(v_cache, paged) if use_paged else v_cache
        out = decode_attention(q, k_att, v_att, pos, rt,
                               softcap=kind.softcap)
    y = linear_apply(p["wo"], out.reshape(b, 1, h * hd))
    return y, {"k": k_cache, "v": v_cache}


def _mla_decode(p, x, cache, pos, rt, cfg: ModelConfig, ropes,
                paged: PagedLayout | None = None):
    m = cfg.mla
    b = x.shape[0]
    cos, sin = ropes[cfg.rope_theta]
    q = linear_apply(p["wq"], x).reshape(b, 1, m.n_heads, m.d_qk)
    q_nope, q_rope = q[..., :m.d_nope], q[..., m.d_nope:]
    q_rope = apply_rotary(q_rope, cos, sin)

    ckv = linear_apply(p["kv_down"], x)
    c_t = rmsnorm_apply(p["kv_norm"], ckv[..., :m.kv_lora])
    kr_t = apply_rotary(ckv[..., None, m.kv_lora:], cos, sin)[:, :, 0]

    c_cache = _update_cache(cache["c"], c_t, pos, window=None, paged=paged)
    r_cache = _update_cache(cache["rope"], kr_t, pos, window=None,
                            paged=paged)
    c_att = _paged_view(c_cache, paged) if paged is not None else c_cache
    r_att = _paged_view(r_cache, paged) if paged is not None else r_cache

    # Absorbed attention in latent space (MQA over one 576-dim head).
    w_up = p["kv_up"]["w"].reshape(m.kv_lora, m.n_heads, m.d_nope + m.d_v)
    w_uk = w_up[..., :m.d_nope]                       # (lora, H, d_nope)
    w_uv = w_up[..., m.d_nope:]                       # (lora, H, d_v)
    q_lat = jnp.einsum("bthn,lhn->bthl", q_nope, w_uk.astype(q_nope.dtype))
    q_eff = jnp.concatenate([q_lat, q_rope], axis=-1)  # (B,1,H,lora+rope)
    k_eff = jnp.concatenate([c_att, r_att], axis=-1)[:, :, None]
    v_eff = jnp.pad(c_att[:, :, None],
                    ((0, 0), (0, 0), (0, 0), (0, m.d_rope)))
    out = decode_attention(q_eff, k_eff, v_eff, pos, rt,
                           scale=1.0 / (m.d_qk ** 0.5), kv_replicated=True)
    out_lat = out[..., :m.kv_lora]                    # (B,1,H,lora)
    o = jnp.einsum("bthl,lhv->bthv", out_lat, w_uv.astype(out_lat.dtype))
    y = linear_apply(p["wo"], o.reshape(b, 1, m.n_heads * m.d_v))
    return y, {"c": c_cache, "rope": r_cache}


def _cross_decode(p, x, cache, rt, cfg: ModelConfig):
    """Cross-attention against the (small, replicated-S) encoder cache."""
    b = x.shape[0]
    h, hd = cfg.n_heads, cfg.hd
    q = linear_apply(p["wq"], x).reshape(b, 1, h, hd)

    def local(q, k, v):
        return flash_attention(q, k, v, causal=False, impl="ref")

    from repro.core.runtime import shard_map_compat as _shard_map
    spec_q = P(rt.batch_axes, None, AXIS_HP, None)
    spec_kv = P(rt.batch_axes, None, AXIS_HP, None)
    out = _shard_map(local, rt.mesh, (spec_q, spec_kv, spec_kv),
                     spec_q)(q, cache["k"], cache["v"])
    return linear_apply(p["wo"], out.reshape(b, 1, h * hd))


# ---------------------------------------------------------------------------
# Decode step (one new token)
# ---------------------------------------------------------------------------

def decode_step(params, caches, tokens, pos, rt: Runtime, cfg: ModelConfig,
                paged: PagedLayout | None = None):
    """tokens: (B, 1) int32; pos: scalar int32 or per-request (B,) int32
    (ragged continuous batching — entries of -1 mark inactive slots).
    ``paged``: when given, full-attention K/V (and MLA latent) caches are
    block pools gathered through per-request block tables (dense/moe
    families).  -> (logits, new_caches)."""
    b = tokens.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    assert paged is None or cfg.family in ("dense", "moe"), cfg.family
    params = cast_params_once(params, cfg)
    x = embed_tokens(params, tokens, cfg)
    positions = pos[:, None] if pos.ndim else jnp.full((b, 1), pos,
                                                       jnp.int32)
    positions = jnp.maximum(positions, 0)     # inactive slots: dummy rope
    ropes = build_ropes(cfg, positions) if cfg.rope else {}
    new_caches = {}

    if cfg.family in ("dense", "moe"):
        period = cfg.period
        kinds = [cfg.attn_kind(i) for i in range(period)]
        if cfg.mla is not None:
            def body(x, xs):
                lp, cache = xs
                h = apply_norm(cfg, lp["ln1"], x)
                h, cache = _mla_decode(lp["attn"], h, cache, pos, rt, cfg,
                                       ropes, paged=paged)
                x = x + h
                h = apply_norm(cfg, lp["ln2"], x)
                if cfg.family == "moe":
                    h, _ = moe_apply(lp["moe"], h, rt, cfg.moe,
                                     seq_sharded=False)
                else:
                    h = glu_mlp_apply(lp["mlp"], h, act=cfg.act)
                return x + h, cache
            x, ncache = maybe_scan(body, x, (params["blocks"][0],
                                      caches["blocks"][0]),
                                   cfg.unroll_loops)
            new_caches["blocks"] = [ncache]
        else:
            def body(x, xs):
                lps, slot_caches = xs
                new_slots = []
                for slot in range(period):
                    lp = lps[slot]
                    cache = slot_caches[slot]
                    h = apply_norm(cfg, lp["ln1"], x)
                    h, cache = _gqa_decode(lp["attn"], x=h, cache=cache,
                                           pos=pos, rt=rt, cfg=cfg,
                                           kind=kinds[slot], ropes=ropes,
                                           paged=paged)
                    if cfg.post_norms:
                        h = apply_norm(cfg, lp["pn1"], h)
                    x = x + h
                    h = apply_norm(cfg, lp["ln2"], x)
                    if cfg.family == "moe":
                        h, _ = moe_apply(lp["moe"], h, rt, cfg.moe,
                                         seq_sharded=False)
                    else:
                        h = glu_mlp_apply(lp["mlp"], h, act=cfg.act)
                    if cfg.post_norms:
                        h = apply_norm(cfg, lp["pn2"], h)
                    x = x + h
                    new_slots.append(cache)
                return x, new_slots
            x, ncaches = maybe_scan(body, x,
                                    (params["blocks"], caches["blocks"]),
                                    cfg.unroll_loops)
            new_caches["blocks"] = ncaches

    elif cfg.family == "ssm":
        def body(x, xs):
            lp, cache = xs
            h = apply_norm(cfg, lp["ln"], x)
            h, cache = mamba1_decode(lp["mix"], h, cache, cfg.ssm1)
            return x + h, cache
        x, ncache = maybe_scan(body, x, (params["blocks"], caches["blocks"]),
                               cfg.unroll_loops)
        new_caches["blocks"] = ncache

    elif cfg.family == "hybrid":
        shared = params["shared_attn"]
        kind = cfg.attn_kind(0)

        def body(x, xs):
            lps, ssm_cache, attn_cache = xs
            new_ssm = []
            for i in range(cfg.attn_every):
                lp = jax.tree.map(lambda t: t[i], lps)
                sc = jax.tree.map(lambda t: t[i], ssm_cache)
                h = apply_norm(cfg, lp["ln"], x)
                h, sc = mamba2_decode(lp["mix"], h, sc, cfg.ssm2)
                x = x + h
                new_ssm.append(sc)
            h = apply_norm(cfg, shared["ln1"], x)
            h, attn_cache = _gqa_decode(shared["attn"], x=h,
                                        cache=attn_cache, pos=pos, rt=rt,
                                        cfg=cfg, kind=kind, ropes=ropes)
            x = x + h
            h = glu_mlp_apply(shared["mlp"],
                              apply_norm(cfg, shared["ln2"], x),
                              act=cfg.act)
            x = x + h
            new_ssm = jax.tree.map(lambda *t: jnp.stack(t), *new_ssm)
            return x, (new_ssm, attn_cache)

        x, (nssm, nattn) = maybe_scan(
            body, x, (params["blocks"], caches["blocks"],
                      caches["shared_attn"]), cfg.unroll_loops)
        new_caches["blocks"] = nssm
        new_caches["shared_attn"] = nattn
        if "blocks_tail" in params:
            def tail(x, xs):
                lp, cache = xs
                h = apply_norm(cfg, lp["ln"], x)
                h, cache = mamba2_decode(lp["mix"], h, cache, cfg.ssm2)
                return x + h, cache
            x, ntail = maybe_scan(tail, x, (params["blocks_tail"],
                                            caches["blocks_tail"]),
                                  cfg.unroll_loops)
            new_caches["blocks_tail"] = ntail

    elif cfg.family == "encdec":
        kind = AttnKind(causal=True, rope=False)
        x = x + embedding_apply(
            params["dec_pos"],
            jnp.minimum(positions, cfg.max_positions - 1), dtype=x.dtype)

        def body(x, xs):
            lp, cache, xcache = xs
            h = apply_norm(cfg, lp["ln1"], x)
            h, cache = _gqa_decode(lp["attn"], x=h, cache=cache, pos=pos,
                                   rt=rt, cfg=cfg, kind=kind, ropes=ropes)
            x = x + h
            x = x + _cross_decode(lp["cross"],
                                  apply_norm(cfg, lp["lnx"], x), xcache, rt,
                                  cfg)
            h = gelu_mlp_apply(lp["mlp"], apply_norm(cfg, lp["ln2"], x))
            return x + h, cache

        x, ncache = maybe_scan(body, x, (params["dec_blocks"],
                                         caches["dec_blocks"],
                                         caches["cross"]), cfg.unroll_loops)
        new_caches["dec_blocks"] = ncache
        new_caches["cross"] = caches["cross"]
    else:
        raise ValueError(cfg.family)

    x = apply_norm(cfg, params["final_norm"], x)
    w = lm_head_weight(params, cfg)
    logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
    # Keep logits vocab-sharded so the LM head never gathers its weight.
    logits = jax.lax.with_sharding_constraint(
        logits, NamedSharding(rt.mesh, P(BATCH_AXES, None, MODEL_AXES)))
    return logits, new_caches


# ---------------------------------------------------------------------------
# Prefill: run the prompt through the trunk, collecting caches
# ---------------------------------------------------------------------------

def _pref_kind(kind: AttnKind) -> AttnKind:
    return kind


def _gqa_prefill(p, x, ropes, rt: Runtime, cfg: ModelConfig,
                 kind: AttnKind):
    """Returns (y, (k, v)) with k/v rotary-applied, contiguous order."""
    from repro.models.attention_block import (_project_qkv, make_2d_cfg)
    from repro.core.attention2d import attention_2d
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                           ropes.get(kind.rope_theta, (None, None))[0],
                           ropes.get(kind.rope_theta, (None, None))[1],
                           kind, qk_norm=cfg.qk_norm)
    cfg2d = make_2d_cfg(rt, kind, zigzag=False)
    out = attention_2d(q, k, v, mesh=rt.mesh, cfg=cfg2d)
    y = linear_apply(p["wo"], out.reshape(b, s, cfg.n_heads * cfg.hd))
    if kind.window is not None:
        k, v = k[:, -kind.window:], v[:, -kind.window:]
    return y, (k, v)


def prefill(params, batch, rt: Runtime, cfg: ModelConfig):
    """batch: {tokens (B,S)[, frames]} (contiguous order, no zigzag).

    Returns (last-token logits (B, 1, V), caches ready for decode_step at
    pos = S).
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    params = cast_params_once(params, cfg)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                 (b, s))
    x = embed_tokens(params, tokens, cfg)
    x = rt.constrain(x, None)
    ropes = build_ropes(cfg, positions) if cfg.rope else {}
    caches = {}

    if cfg.family in ("dense", "moe"):
        period = cfg.period
        kinds = [cfg.attn_kind(i) for i in range(period)]
        if cfg.mla is not None:
            m = cfg.mla

            def body(x, lp):
                h = apply_norm(cfg, lp["ln1"], x)
                # latent cache entries
                ckv = linear_apply(lp["attn"]["kv_down"], h)
                c_t = rmsnorm_apply(lp["attn"]["kv_norm"],
                                    ckv[..., :m.kv_lora])
                cos, sin = ropes[cfg.rope_theta]
                kr = apply_rotary(ckv[..., None, m.kv_lora:], cos,
                                  sin)[:, :, 0]
                from repro.models.attention_block import mla_apply
                kind = AttnKind(causal=True, rope=True,
                                rope_theta=cfg.rope_theta)
                h2 = mla_apply(lp["attn"], h, cos, sin, rt, kind, m,
                               zigzag=False)
                x = x + h2
                h = apply_norm(cfg, lp["ln2"], x)
                if cfg.family == "moe":
                    h, _ = moe_apply(lp["moe"], h, rt, cfg.moe)
                else:
                    h = glu_mlp_apply(lp["mlp"], h, act=cfg.act)
                return x + h, {"c": c_t, "rope": kr}

            x, ncache = maybe_scan(body, x, params["blocks"][0], cfg.unroll_loops)
            caches["blocks"] = [ncache]
        else:
            def body(x, lps):
                slot_caches = []
                for slot in range(period):
                    lp = lps[slot]
                    h = apply_norm(cfg, lp["ln1"], x)
                    h, kv = _gqa_prefill(lp["attn"], h, ropes, rt, cfg,
                                         kinds[slot])
                    if cfg.post_norms:
                        h = apply_norm(cfg, lp["pn1"], h)
                    x = x + h
                    h = apply_norm(cfg, lp["ln2"], x)
                    if cfg.family == "moe":
                        h, _ = moe_apply(lp["moe"], h, rt, cfg.moe)
                    else:
                        h = glu_mlp_apply(lp["mlp"], h, act=cfg.act)
                    if cfg.post_norms:
                        h = apply_norm(cfg, lp["pn2"], h)
                    x = x + h
                    slot_caches.append({"k": kv[0], "v": kv[1]})
                return x, slot_caches

            x, ncaches = maybe_scan(body, x, params["blocks"], cfg.unroll_loops)
            caches["blocks"] = ncaches

    elif cfg.family == "ssm":
        from repro.models.ssm import mamba1_apply

        def body(x, lp):
            h = apply_norm(cfg, lp["ln"], x)
            h, st = mamba1_apply(lp["mix"], h, rt, cfg.ssm1,
                                 return_state=True)
            return x + h, st
        x, st = maybe_scan(body, x, params["blocks"], cfg.unroll_loops)
        caches["blocks"] = st

    elif cfg.family == "hybrid":
        from repro.models.ssm import mamba2_apply
        shared = params["shared_attn"]
        kind = cfg.attn_kind(0)

        def body(x, lps):
            states = []
            for i in range(cfg.attn_every):
                lp = jax.tree.map(lambda t: t[i], lps)
                h = apply_norm(cfg, lp["ln"], x)
                h, st = mamba2_apply(lp["mix"], h, rt, cfg.ssm2,
                                     return_state=True)
                x = x + h
                states.append(st)
            h = apply_norm(cfg, shared["ln1"], x)
            h, kv = _gqa_prefill(shared["attn"], h, ropes, rt, cfg, kind)
            x = x + h
            h = glu_mlp_apply(shared["mlp"],
                              apply_norm(cfg, shared["ln2"], x), act=cfg.act)
            x = x + h
            states = jax.tree.map(lambda *t: jnp.stack(t), *states)
            return x, (states, {"k": kv[0], "v": kv[1]})

        x, (nssm, nattn) = maybe_scan(body, x, params["blocks"], cfg.unroll_loops)
        caches["blocks"] = nssm
        caches["shared_attn"] = nattn
        if "blocks_tail" in params:
            def tail(x, lp):
                h = apply_norm(cfg, lp["ln"], x)
                h, st = mamba2_apply(lp["mix"], h, rt, cfg.ssm2,
                                     return_state=True)
                return x + h, st
            x, st = maybe_scan(tail, x, params["blocks_tail"], cfg.unroll_loops)
            caches["blocks_tail"] = st

    elif cfg.family == "encdec":
        from repro.models.model import whisper_encoder
        enc = whisper_encoder(params, batch["frames"], rt, cfg)
        kind = AttnKind(causal=True, rope=False)
        x = x + embedding_apply(
            params["dec_pos"],
            jnp.minimum(positions, cfg.max_positions - 1), dtype=x.dtype)

        def body(x, lp):
            h = apply_norm(cfg, lp["ln1"], x)
            h, kv = _gqa_prefill(lp["attn"], h, ropes, rt, cfg, kind)
            x = x + h
            xk = linear_apply(lp["cross"]["wk"], enc).reshape(
                enc.shape[0], enc.shape[1], cfg.n_heads, cfg.hd)
            xv = linear_apply(lp["cross"]["wv"], enc).reshape(
                enc.shape[0], enc.shape[1], cfg.n_heads, cfg.hd)
            from repro.models.attention_block import cross_attn_apply
            x = x + cross_attn_apply(lp["cross"],
                                     apply_norm(cfg, lp["lnx"], x), enc, rt,
                                     n_heads=cfg.n_heads, head_dim=cfg.hd)
            h = gelu_mlp_apply(lp["mlp"], apply_norm(cfg, lp["ln2"], x))
            return x + h, ({"k": kv[0], "v": kv[1]},
                           {"k": xk, "v": xv})

        x, (selfc, crossc) = maybe_scan(body, x, params["dec_blocks"],
                                    cfg.unroll_loops)
        caches["dec_blocks"] = selfc
        caches["cross"] = crossc
    else:
        raise ValueError(cfg.family)

    x = apply_norm(cfg, params["final_norm"], x)
    x_last = x[:, -1:]
    w = lm_head_weight(params, cfg)
    logits = (x_last @ w.astype(x_last.dtype)).astype(jnp.float32)
    logits = jax.lax.with_sharding_constraint(
        logits, NamedSharding(rt.mesh, P(BATCH_AXES, None, MODEL_AXES)))
    return logits, caches


# ---------------------------------------------------------------------------
# Chunked prefill against a paged cache (serve-engine building block)
# ---------------------------------------------------------------------------

def prefill_chunk(params, caches, tokens, start, valid, rt: Runtime,
                  cfg: ModelConfig, paged: PagedLayout):
    """One prefill chunk against the paged cache (dense/moe families).

    tokens (B, Lc) int32 — a bucketed chunk (rows ≥ ``valid`` are padding);
    start scalar/(B,) int32 — logical position of ``tokens[:, 0]``;
    valid scalar/(B,) int32 — real tokens in this chunk (≤ Lc).

    Full-attention layers write the chunk's K/V through the block tables,
    then attend the gathered pages with a ``start``-anchored causal band
    capped at ``start + valid`` visible keys.  Sliding-window layers
    require single-chunk prefill (``start == 0`` covering the whole
    prompt): chunk-local banded attention is exact there, and the ring
    buffer is seeded with the last ``min(window, valid)`` positions.  MLA
    runs absorbed against the gathered latent pages.  Masks are ragged
    (per-request offsets) => ref attention path.

    Returns (logits of token ``valid - 1`` per request (B, 1, V),
    new_caches).
    """
    assert cfg.family in ("dense", "moe"), cfg.family
    b, lc = tokens.shape
    params = cast_params_once(params, cfg)
    x = embed_tokens(params, tokens, cfg)
    start = jnp.asarray(start, jnp.int32)
    valid = jnp.asarray(valid, jnp.int32)
    start_c = start.reshape(-1, 1)
    valid_c = valid.reshape(-1, 1)
    positions = jnp.broadcast_to(
        jnp.maximum(start_c + jnp.arange(lc, dtype=jnp.int32)[None], 0),
        (b, lc))
    ropes = build_ropes(cfg, positions) if cfg.rope else {}
    period = cfg.period
    kinds = [cfg.attn_kind(i) for i in range(period)]

    from repro.models.attention_block import _project_qkv

    def gqa_chunk(p, h, cache, kind: AttnKind):
        cos, sin = ropes.get(kind.rope_theta, (None, None))
        q, k, v = _project_qkv(p, h, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                               cos, sin, kind, qk_norm=cfg.qk_norm)
        if kind.window is not None:
            w = kind.window
            out, _ = flash_fwd_chunk(q, k, v, causal=True, window=w,
                                     softcap=kind.softcap,
                                     kv_valid_len=valid, impl="ref")
            # Seed the ring buffer with the last min(w, valid) positions —
            # each lands in its decode slot ``pos % w``; the rest (and the
            # padded rows) are routed out of bounds and dropped.
            t = jnp.arange(lc, dtype=jnp.int32)[None]
            keep = (t < valid_c) & (t >= valid_c - w)
            slot = jnp.where(keep, t % w, w)
            bidx = jnp.arange(b)[:, None]
            kc = cache["k"].at[bidx, slot].set(
                k.astype(cache["k"].dtype), mode="drop")
            vc = cache["v"].at[bidx, slot].set(
                v.astype(cache["v"].dtype), mode="drop")
        else:
            kc = _paged_write_chunk(cache["k"], k, start, valid, paged)
            vc = _paged_write_chunk(cache["v"], v, start, valid, paged)
            out, _ = flash_fwd_chunk(q, _paged_view(kc, paged),
                                     _paged_view(vc, paged), causal=True,
                                     softcap=kind.softcap,
                                     mask_offset=start,
                                     kv_valid_len=start + valid,
                                     impl="ref")
        y = linear_apply(p["wo"], out.reshape(b, lc, cfg.n_heads * cfg.hd))
        return y, {"k": kc, "v": vc}

    def mla_chunk(p, h, cache):
        m = cfg.mla
        cos, sin = ropes[cfg.rope_theta]
        q = linear_apply(p["wq"], h).reshape(b, lc, m.n_heads, m.d_qk)
        q_nope, q_rope = q[..., :m.d_nope], q[..., m.d_nope:]
        q_rope = apply_rotary(q_rope, cos, sin)
        ckv = linear_apply(p["kv_down"], h)
        c_t = rmsnorm_apply(p["kv_norm"], ckv[..., :m.kv_lora])
        kr_t = apply_rotary(ckv[..., None, m.kv_lora:], cos, sin)[:, :, 0]
        cc = _paged_write_chunk(cache["c"], c_t, start, valid, paged)
        rc = _paged_write_chunk(cache["rope"], kr_t, start, valid, paged)
        c_att = _paged_view(cc, paged)
        r_att = _paged_view(rc, paged)
        w_up = p["kv_up"]["w"].reshape(m.kv_lora, m.n_heads,
                                       m.d_nope + m.d_v)
        q_lat = jnp.einsum("bthn,lhn->bthl", q_nope,
                           w_up[..., :m.d_nope].astype(q_nope.dtype))
        q_eff = jnp.concatenate([q_lat, q_rope], axis=-1)
        k_eff = jnp.concatenate([c_att, r_att], axis=-1)[:, :, None]
        v_eff = jnp.pad(c_att[:, :, None],
                        ((0, 0), (0, 0), (0, 0), (0, m.d_rope)))
        out, _ = flash_fwd_chunk(q_eff, k_eff, v_eff, causal=True,
                                 scale=1.0 / (m.d_qk ** 0.5),
                                 mask_offset=start,
                                 kv_valid_len=start + valid, impl="ref")
        out_lat = out[..., :m.kv_lora]
        o = jnp.einsum("bthl,lhv->bthv", out_lat,
                       w_up[..., m.d_nope:].astype(out_lat.dtype))
        return linear_apply(p["wo"], o.reshape(b, lc, m.n_heads * m.d_v)), \
            {"c": cc, "rope": rc}

    if cfg.mla is not None:
        def body(x, xs):
            lp, cache = xs
            h = apply_norm(cfg, lp["ln1"], x)
            h, cache = mla_chunk(lp["attn"], h, cache)
            x = x + h
            h = apply_norm(cfg, lp["ln2"], x)
            if cfg.family == "moe":
                h, _ = moe_apply(lp["moe"], h, rt, cfg.moe,
                                 seq_sharded=False)
            else:
                h = glu_mlp_apply(lp["mlp"], h, act=cfg.act)
            return x + h, cache

        x, ncache = maybe_scan(body, x, (params["blocks"][0],
                                         caches["blocks"][0]),
                               cfg.unroll_loops)
        new_caches = {"blocks": [ncache]}
    else:
        def body(x, xs):
            lps, slot_caches = xs
            new_slots = []
            for slot in range(period):
                lp, cache = lps[slot], slot_caches[slot]
                h = apply_norm(cfg, lp["ln1"], x)
                h, cache = gqa_chunk(lp["attn"], h, cache, kinds[slot])
                if cfg.post_norms:
                    h = apply_norm(cfg, lp["pn1"], h)
                x = x + h
                h = apply_norm(cfg, lp["ln2"], x)
                if cfg.family == "moe":
                    h, _ = moe_apply(lp["moe"], h, rt, cfg.moe,
                                     seq_sharded=False)
                else:
                    h = glu_mlp_apply(lp["mlp"], h, act=cfg.act)
                if cfg.post_norms:
                    h = apply_norm(cfg, lp["pn2"], h)
                x = x + h
                new_slots.append(cache)
            return x, new_slots

        x, ncaches = maybe_scan(body, x, (params["blocks"],
                                          caches["blocks"]),
                                cfg.unroll_loops)
        new_caches = {"blocks": ncaches}

    x = apply_norm(cfg, params["final_norm"], x)
    idx = jnp.clip(jnp.broadcast_to(valid.reshape(-1), (b,)) - 1, 0, lc - 1)
    x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
    w = lm_head_weight(params, cfg)
    logits = (x_last @ w.astype(x_last.dtype)).astype(jnp.float32)
    logits = jax.lax.with_sharding_constraint(
        logits, NamedSharding(rt.mesh, P(rt.batch_axes, None, MODEL_AXES)))
    return logits, new_caches
