"""Mixture-of-Experts FFN with expert parallelism over the sp axes.

Expert parallelism is orthogonal to 2D-Attention and reuses its mesh: the
experts are sharded over ``(head, outer, inner)`` (= d_sp ranks per data
group) and tokens are exchanged with a *hierarchical* all-to-all — one
``lax.all_to_all`` per mesh axis, splitting the expert dim and concatenating
the capacity dim.  The composition of the three exchanges is the full
``d_sp``-way dispatch, with the expert-ownership digits (head, outer, inner)
matching the weights' PartitionSpec, and the return path applies the inverse
exchanges in reverse order.

Routing is capacity-based (deterministic shapes for SPMD): top-k with
per-expert capacity ``ceil(T·k/E · cf)``; overflow tokens fall through with
only the shared-expert/residual contribution.  A switch-style load-balance
aux loss is pmean'd across the mesh.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.runtime import shard_map_compat as _shard_map
from repro.core.runtime import Runtime
from repro.core.topology import (AXIS_HP, AXIS_INNER, AXIS_OUTER, BATCH_AXES,
                                 MESH_AXES, SEQ_AXES)
from repro.models.layers import _normal, glu_mlp_apply, init_glu_mlp

EP_AXES = (AXIS_HP, AXIS_OUTER, AXIS_INNER)


@dataclasses.dataclass(frozen=True)
class MoEDims:
    d_model: int
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert intermediate
    n_shared: int = 0              # shared (always-on) experts
    capacity_factor: float = 1.25
    norm_topk: bool = True         # qwen3: renormalize top-k weights
    routed_scale: float = 1.0
    aux_weight: float = 1e-3


def init_moe(key, m: MoEDims):
    ks = jax.random.split(key, 5)
    std = m.d_model ** -0.5
    p = {
        "router": _normal(ks[0], (m.d_model, m.n_experts), std),
        "w1": _normal(ks[1], (m.n_experts, m.d_model, m.d_ff), std),
        "w3": _normal(ks[2], (m.n_experts, m.d_model, m.d_ff), std),
        "w2": _normal(ks[3], (m.n_experts, m.d_ff, m.d_model),
                      m.d_ff ** -0.5),
    }
    if m.n_shared:
        p["shared"] = init_glu_mlp(ks[4], m.d_model, m.d_ff * m.n_shared)
    return p


def _ep_sizes(rt: Runtime):
    pc = rt.pc
    return {AXIS_HP: pc.hp, AXIS_OUTER: pc.cp_outer, AXIS_INNER: pc.cp_inner}


def moe_apply(p, x, rt: Runtime, m: MoEDims, seq_sharded: bool = True):
    """x: (B, S, D) seq-sharded.  Returns (y, aux_loss_scalar).

    ``seq_sharded=False`` is the decode path (S=1 cannot shard over sp):
    tokens are replicated across the sp ranks of each data group, so the
    expert compute is duplicated sp-fold — negligible at decode batch
    sizes, and flagged in EXPERIMENTS.md §Perf as a serving optimization
    (dispatch from a batch-resharded layout).
    """
    sizes = _ep_sizes(rt)
    ep = rt.pc.sp
    assert m.n_experts % ep == 0, (m.n_experts, ep)

    def local(x, router, w1, w3, w2):
        b_loc, s_loc, d = x.shape
        t = b_loc * s_loc
        cap = max(4, int(-(-t * m.top_k * m.capacity_factor
                           // m.n_experts)))
        xt = x.reshape(t, d)

        logits = (xt.astype(jnp.float32) @ router.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)                  # (T, E)
        topw, topi = lax.top_k(probs, m.top_k)                   # (T, k)
        if m.norm_topk:
            topw = topw / jnp.sum(topw, axis=-1, keepdims=True)
        topw = topw * m.routed_scale

        flat_e = topi.reshape(-1)                                # (T*k,)
        flat_w = topw.reshape(-1)
        tok_ix = jnp.repeat(jnp.arange(t), m.top_k)
        onehot = jax.nn.one_hot(flat_e, m.n_experts, dtype=jnp.int32)
        pos = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=-1) - 1
        keep = (pos < cap)

        buf = jnp.zeros((m.n_experts, cap, d), x.dtype)
        buf = buf.at[flat_e, jnp.clip(pos, 0, cap - 1)].add(
            jnp.where(keep[:, None], xt[tok_ix], 0.0),
            mode="drop")

        # --- dispatch: expert dim out, capacity dim in ------------------
        for ax in EP_AXES:
            if sizes[ax] > 1:
                buf = lax.all_to_all(buf, ax, 0, 1, tiled=True)
        # buf: (E/ep, cap*ep, D) — this rank's experts, everyone's tokens.

        h1 = jnp.einsum("ecd,edf->ecf", buf, w1.astype(buf.dtype))
        h3 = jnp.einsum("ecd,edf->ecf", buf, w3.astype(buf.dtype))
        hout = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h1) * h3,
                          w2.astype(buf.dtype))

        # --- return path: inverse exchanges, reverse order --------------
        for ax in reversed(EP_AXES):
            if sizes[ax] > 1:
                hout = lax.all_to_all(hout, ax, 1, 0, tiled=True)
        # hout: (E, cap, D)

        gathered = hout[flat_e, jnp.clip(pos, 0, cap - 1)]       # (T*k, D)
        gathered = jnp.where(keep[:, None], gathered, 0.0)
        y = jnp.zeros((t, d), jnp.float32)
        y = y.at[tok_ix].add(gathered.astype(jnp.float32)
                             * flat_w[:, None])
        y = y.reshape(b_loc, s_loc, d).astype(x.dtype)

        # Switch-style load-balance loss (fraction routed × mean prob).
        frac = jnp.mean(
            jnp.sum(jax.nn.one_hot(topi, m.n_experts), axis=1), axis=0)
        mean_p = jnp.mean(probs, axis=0)
        aux = m.n_experts * jnp.sum(frac * mean_p)
        aux = lax.pmean(aux, MESH_AXES)
        return y, aux

    spec_x = P(rt.batch_axes, SEQ_AXES, None) if seq_sharded \
        else P(rt.batch_axes, None, None)
    spec_e = P(EP_AXES, None, None)
    f = _shard_map(local, rt.mesh,
                   (spec_x, P(None, None), spec_e, spec_e, spec_e),
                   (spec_x, P()))
    y, aux = f(x, p["router"], p["w1"], p["w3"], p["w2"])

    if m.n_shared:
        y = y + glu_mlp_apply(p["shared"], x, act="silu")
    return y, m.aux_weight * aux
