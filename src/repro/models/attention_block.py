"""Attention blocks: GQA (optionally qk-norm / softcap / sliding window),
DeepSeek MLA, Whisper cross-attention — all running on 2D-Attention.

Train path uses ``attention_2d``; decode paths use flash-decoding style
lse-combines across the context axes (``decode_attention``).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.attention2d import (Attn2DConfig, attention_2d,
                                    attn2d_config)
from repro.core.runtime import shard_map_compat as _shard_map
from repro.core.runtime import Runtime
from repro.core.topology import (AXIS_HP, AXIS_INNER, AXIS_OUTER, BATCH_AXES,
                                 SEQ_AXES)
from repro.kernels.ops import flash_fwd_chunk
from repro.kernels.ref import NEG_INF
from repro.models.layers import (apply_rotary, init_linear, init_rmsnorm,
                                 linear_apply, rmsnorm_apply)


@dataclasses.dataclass(frozen=True)
class AttnKind:
    """Per-layer attention behaviour."""
    causal: bool = True
    window: int | None = None     # sliding-window (local) layers
    softcap: float = 0.0
    rope: bool = True
    rope_theta: float = 10000.0


def make_2d_cfg(rt: Runtime, kind: AttnKind, *, zigzag: bool,
                scale: float | None = None) -> Attn2DConfig:
    return attn2d_config(rt.pc, impl=rt.impl, causal=kind.causal,
                         zigzag=zigzag, window=kind.window,
                         softcap=kind.softcap, scale=scale)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------

def init_gqa(key, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
             *, qk_norm: bool = False, bias: bool = False):
    ks = jax.random.split(key, 4)
    p = {"wq": init_linear(ks[0], d_model, n_heads * head_dim, bias=bias),
         "wk": init_linear(ks[1], d_model, n_kv_heads * head_dim, bias=bias),
         "wv": init_linear(ks[2], d_model, n_kv_heads * head_dim, bias=bias),
         "wo": init_linear(ks[3], n_heads * head_dim, d_model)}
    if qk_norm:
        p["qn"] = init_rmsnorm(head_dim)
        p["kn"] = init_rmsnorm(head_dim)
    return p


def _project_qkv(p, x, n_heads, n_kv_heads, head_dim, cos, sin,
                 kind: AttnKind, *, qk_norm: bool):
    b, s, _ = x.shape
    q = linear_apply(p["wq"], x).reshape(b, s, n_heads, head_dim)
    k = linear_apply(p["wk"], x).reshape(b, s, n_kv_heads, head_dim)
    v = linear_apply(p["wv"], x).reshape(b, s, n_kv_heads, head_dim)
    if qk_norm:
        q = rmsnorm_apply(p["qn"], q)
        k = rmsnorm_apply(p["kn"], k)
    if kind.rope:
        q = apply_rotary(q, cos, sin)
        k = apply_rotary(k, cos, sin)
    return q, k, v


def gqa_apply(p, x, cos, sin, rt: Runtime, kind: AttnKind, *,
              n_heads: int, n_kv_heads: int, head_dim: int,
              qk_norm: bool = False, zigzag: bool = True,
              scale: float | None = None, doc_start=None):
    """x: (B, S, D) -> (B, S, D).  cos/sin: (B, S, head_dim/2).
    ``doc_start``: (B, S) packed-document boundary table (see
    attention_2d)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, head_dim, cos, sin,
                           kind, qk_norm=qk_norm)
    cfg = make_2d_cfg(rt, kind, zigzag=zigzag, scale=scale)
    out = attention_2d(q, k, v, mesh=rt.mesh, cfg=cfg, doc_start=doc_start)
    out = checkpoint_name(out, "attn_out")   # Selective Checkpoint++
    return linear_apply(p["wo"], out.reshape(b, s, n_heads * head_dim))


# ---------------------------------------------------------------------------
# DeepSeek-V2 MLA block (latent-compressed KV)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLADims:
    n_heads: int = 16
    kv_lora: int = 512
    d_nope: int = 128
    d_rope: int = 64
    d_v: int = 128

    @property
    def d_qk(self) -> int:
        return self.d_nope + self.d_rope


def init_mla(key, d_model: int, m: MLADims):
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], d_model, m.n_heads * m.d_qk),
        "kv_down": init_linear(ks[1], d_model, m.kv_lora + m.d_rope),
        "kv_norm": init_rmsnorm(m.kv_lora),
        "kv_up": init_linear(ks[2], m.kv_lora,
                             m.n_heads * (m.d_nope + m.d_v)),
        "wo": init_linear(ks[3], m.n_heads * m.d_v, d_model),
    }


def mla_apply(p, x, cos, sin, rt: Runtime, kind: AttnKind, m: MLADims, *,
              zigzag: bool = True, doc_start=None):
    """Training path: up-project the latent, run standard 2D-Attention.

    cos/sin must be built for head_dim = d_rope.
    """
    b, s, _ = x.shape
    q = linear_apply(p["wq"], x).reshape(b, s, m.n_heads, m.d_qk)
    q_nope, q_rope = q[..., :m.d_nope], q[..., m.d_nope:]
    q_rope = apply_rotary(q_rope, cos, sin)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    ckv = linear_apply(p["kv_down"], x)
    c = rmsnorm_apply(p["kv_norm"], ckv[..., :m.kv_lora])
    k_rope = apply_rotary(ckv[..., None, m.kv_lora:], cos, sin)  # (B,S,1,dr)

    kv = linear_apply(p["kv_up"], c).reshape(b, s, m.n_heads,
                                             m.d_nope + m.d_v)
    k_nope, v = kv[..., :m.d_nope], kv[..., m.d_nope:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, m.n_heads, m.d_rope))],
        axis=-1)
    # Pad V to the QK head dim so the flash kernel tiles uniformly.
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, m.d_qk - m.d_v)))

    cfg = make_2d_cfg(rt, kind, zigzag=zigzag,
                      scale=1.0 / (m.d_qk ** 0.5))
    out = attention_2d(q, k, v_pad, mesh=rt.mesh, cfg=cfg,
                       doc_start=doc_start)[..., :m.d_v]
    out = checkpoint_name(out, "attn_out")
    return linear_apply(p["wo"], out.reshape(b, s, m.n_heads * m.d_v))


# ---------------------------------------------------------------------------
# Whisper cross-attention (encoder KV is small: gather + head-parallel)
# ---------------------------------------------------------------------------

def init_cross_attn(key, d_model: int, n_heads: int, head_dim: int):
    ks = jax.random.split(key, 4)
    return {"wq": init_linear(ks[0], d_model, n_heads * head_dim, bias=True),
            "wk": init_linear(ks[1], d_model, n_heads * head_dim),
            "wv": init_linear(ks[2], d_model, n_heads * head_dim, bias=True),
            "wo": init_linear(ks[3], n_heads * head_dim, d_model)}


def cross_attn_apply(p, x, enc, rt: Runtime, *, n_heads: int,
                     head_dim: int):
    """x: decoder (B, S_dec, D) seq-sharded; enc: (B, S_enc, D) seq-sharded.

    The encoder context (<=1500 frames) is far too short to ring: gather it
    over the sp axes inside the region and head-parallelize only.
    """
    b, s, _ = x.shape
    q = linear_apply(p["wq"], x).reshape(b, s, n_heads, head_dim)
    k = linear_apply(p["wk"], enc).reshape(b, enc.shape[1], n_heads, head_dim)
    v = linear_apply(p["wv"], enc).reshape(b, enc.shape[1], n_heads, head_dim)

    hp = rt.pc.hp
    impl = rt.impl

    def local(q, k, v):
        if hp > 1:
            q = lax.all_to_all(q, AXIS_HP, 2, 1, tiled=True)
        kf = lax.all_gather(k, SEQ_AXES, axis=1, tiled=True)
        vf = lax.all_gather(v, SEQ_AXES, axis=1, tiled=True)
        if hp > 1:
            h_loc = kf.shape[2] // hp
            h0 = lax.axis_index(AXIS_HP) * h_loc
            kf = lax.dynamic_slice_in_dim(kf, h0, h_loc, axis=2)
            vf = lax.dynamic_slice_in_dim(vf, h0, h_loc, axis=2)
        from repro.kernels.ops import flash_attention
        out = flash_attention(q, kf, vf, causal=False, impl=impl)
        if hp > 1:
            out = lax.all_to_all(out, AXIS_HP, 1, 2, tiled=True)
        return out

    spec = P(BATCH_AXES, SEQ_AXES, None, None)
    out = _shard_map(local, rt.mesh, (spec, spec, spec), spec)(q, k, v)
    out = checkpoint_name(out, "attn_out")
    return linear_apply(p["wo"], out.reshape(b, s, n_heads * head_dim))


# ---------------------------------------------------------------------------
# Decode: flash-decoding lse-combine across the context axes
# ---------------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, pos, rt: Runtime, *,
                     softcap: float = 0.0, window: int | None = None,
                     scale: float | None = None, ring_full=None,
                     kv_replicated: bool = False):
    """One-token attention against a context-sharded KV cache.

    q: (B, 1, H, d) — heads sharded over the head axis by GSPMD.
    k_cache/v_cache: (B, S_max, Hkv, d) — S sharded over (outer, inner),
    heads over the head axis (or replicated when ``kv_replicated`` — the
    MLA latent cache is a single logical head).  ``pos``: current
    length - 1, either a scalar int32 (uniform batch) or a per-request
    ``(B,)`` vector (ragged continuous-batching decode; entries of ``-1``
    mark inactive slots, which see no keys and emit zeros).

    ``ring_full``: for sliding-window ring-buffer caches — the (traced)
    number of live slots (scalar or ``(B,)``); every live slot is
    attendable (no causal band).

    Every context rank computes partial attention over its cache shard with
    a masked valid length, then one pmax+psum pair combines the partials —
    flash-decoding on the 2D grid (no ring needed for q_len = 1).
    """
    cp_axes = (AXIS_OUTER, AXIS_INNER)
    have_full = ring_full is not None
    extras = (jnp.asarray(pos, jnp.int32),)
    if have_full:
        extras += (jnp.asarray(ring_full, jnp.int32),)

    def local(q, kc, vc, *extras_l):
        pos_l = extras_l[0]
        shard_len = kc.shape[1]
        r = lax.axis_index(AXIS_OUTER) * rt.pc.cp_inner + \
            lax.axis_index(AXIS_INNER)
        start = r * shard_len
        if have_full:
            valid = jnp.clip(extras_l[1] - start, 0, shard_len)
            out, lse = flash_fwd_chunk(q, kc, vc, causal=False,
                                       softcap=softcap, scale=scale,
                                       kv_valid_len=valid, impl="ref")
        else:
            # Causal + (optional) window masking in one banded mask: the new
            # token sits at global position ``pos``; this shard's keys start
            # at ``start`` => band offset pos - start (traced => ref path).
            out, lse = flash_fwd_chunk(q, kc, vc, causal=True, window=window,
                                       softcap=softcap, scale=scale,
                                       mask_offset=pos_l - start, impl="ref")
        m = lax.pmax(lse, cp_axes)                       # (b, h, 1)
        m_safe = jnp.where(m <= NEG_INF / 2, 0.0, m)
        wgt = jnp.exp(lse - m_safe)
        wgt = jnp.where(lse <= NEG_INF / 2, 0.0, wgt)
        w_o = jnp.transpose(wgt, (0, 2, 1))[..., None]   # (b, 1, h, 1)
        num = lax.psum(out.astype(jnp.float32) * w_o, cp_axes)
        den = lax.psum(wgt, cp_axes)
        den = jnp.where(den == 0.0, 1.0, den)
        return (num / jnp.transpose(den, (0, 2, 1))[..., None]).astype(
            q.dtype)

    spec_q = P(rt.batch_axes, None, AXIS_HP, None)
    spec_kv = P(rt.batch_axes, (AXIS_OUTER, AXIS_INNER),
                None if kv_replicated else AXIS_HP, None)
    spec_x = tuple(P(rt.batch_axes) if e.ndim else P() for e in extras)
    return _shard_map(local, rt.mesh, (spec_q, spec_kv, spec_kv) + spec_x,
                      spec_q)(q, k_cache, v_cache, *extras)
