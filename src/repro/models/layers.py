"""Layer zoo: norms, rotary, MLPs, embeddings.

Pure-functional style: ``init_*`` builds a param dict, ``*_apply`` consumes
it.  Params are stored fp32; compute casts to ``dtype`` at the call site.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _normal(key, shape, std):
    return (std * jax.random.normal(key, shape)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(dim: int):
    return {"w": jnp.zeros((dim,), jnp.float32)}


def rmsnorm_apply(p, x, *, eps: float = 1e-6, gemma_style: bool = True):
    """RMSNorm with (1 + w) scaling (zeros-init w == identity scale).

    ``gemma_style`` keeps the normalization in fp32 (all our archs do).
    """
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    return (xf * (1.0 + p["w"].astype(jnp.float32))).astype(dt)


def layernorm_nonparametric(x, *, eps: float = 1e-5):
    """OLMo-style non-parametric LayerNorm (no scale/bias)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(dt)


def init_layernorm(dim: int):
    return {"w": jnp.ones((dim,), jnp.float32),
            "b": jnp.zeros((dim,), jnp.float32)}


def layernorm_apply(p, x, *, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (xf * p["w"] + p["b"]).astype(dt)


# ---------------------------------------------------------------------------
# Linear / Embedding
# ---------------------------------------------------------------------------

def init_linear(key, d_in: int, d_out: int, *, bias: bool = False,
                std: float | None = None):
    std = std if std is not None else d_in ** -0.5
    p = {"w": _normal(key, (d_in, d_out), std)}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def linear_apply(p, x):
    w = p["w"].astype(x.dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_embedding(key, vocab: int, dim: int, *, std: float = 0.02):
    return {"table": _normal(key, (vocab, dim), std)}


def embedding_apply(p, ids, *, dtype, scale: float | None = None):
    out = jnp.take(p["table"], ids, axis=0).astype(dtype)
    if scale is not None:
        out = out * jnp.asarray(scale, dtype)
    return out


# ---------------------------------------------------------------------------
# Rotary
# ---------------------------------------------------------------------------

def rotary_cos_sin(positions, head_dim: int, *, theta: float = 10000.0,
                   dtype=jnp.float32):
    """positions: (..., S) int -> cos/sin (..., S, head_dim/2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (np.arange(0, half) * 2.0 / head_dim))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rotary(x, cos, sin):
    """x: (B, S, H, D); cos/sin (B, S, D/2) — pairs-as-halves convention."""
    dt = x.dtype
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(
        jnp.float32)
    c = cos[:, :, None, :].astype(jnp.float32)
    s = sin[:, :, None, :].astype(jnp.float32)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(dt)


def sinusoid_positions(s: int, dim: int, dtype=jnp.float32):
    """Whisper-style sinusoidal position embedding (S, D)."""
    half = dim // 2
    scale = np.log(10000.0) / max(half - 1, 1)
    freqs = np.exp(-scale * np.arange(half))
    ang = np.arange(s)[:, None] * freqs[None, :]
    return jnp.asarray(np.concatenate([np.sin(ang), np.cos(ang)], axis=1),
                       dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_glu_mlp(key, d_model: int, d_ff: int):
    """SwiGLU/GeGLU family: W2(act(W1 x) * W3 x)."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w1": init_linear(k1, d_model, d_ff),
            "w3": init_linear(k2, d_model, d_ff),
            "w2": init_linear(k3, d_ff, d_model)}


def glu_mlp_apply(p, x, *, act: str = "silu"):
    h = linear_apply(p["w1"], x)
    if act == "silu":
        h = jax.nn.silu(h)
    elif act == "gelu":
        h = jax.nn.gelu(h, approximate=True)
    else:
        raise ValueError(act)
    return linear_apply(p["w2"], h * linear_apply(p["w3"], x))


def init_gelu_mlp(key, d_model: int, d_ff: int, *, bias: bool = True):
    """Plain 2-matmul GELU MLP (Whisper)."""
    k1, k2 = jax.random.split(key)
    return {"fc1": init_linear(k1, d_model, d_ff, bias=bias),
            "fc2": init_linear(k2, d_ff, d_model, bias=bias)}


def gelu_mlp_apply(p, x):
    return linear_apply(p["fc2"], jax.nn.gelu(linear_apply(p["fc1"], x),
                                              approximate=True))


def softcap(x, cap: float):
    return cap * jnp.tanh(x / cap) if cap else x
