"""Mamba1 / Mamba2 blocks with context-parallel chunked selective scan.

LoongTrain's 2D-Attention does not apply to attention-free layers (DESIGN.md
§Arch-applicability), but its *context* dimension does: the sequence stays
sharded over all sp axes and the recurrence crosses shard boundaries through
a tiny state hand-off:

* the per-chunk cumulative decay has a closed form (``exp(A · ΣΔ)`` — A is
  diagonal for Mamba1, scalar-per-head for Mamba2), so
* each rank runs its local scan from ``h0 = 0``, all ranks ``all_gather``
  their ``(chunk_decay, chunk_state)`` pair (a few MB), every rank computes
  its exclusive prefix locally, and a second local scan runs with the
  corrected ``h0``.  The rescan costs < 2 % extra FLOPs (the scan is ~N/D of
  the block's work) and avoids materializing (S, d_inner, N) corrections.

The causal depthwise conv crosses shards with a (d_conv-1)-token halo
ppermute (no wraparound: rank 0 sees zeros, which is the causal pad).

Memory: the intra-chunk scan runs segment-wise (``lax.scan`` over segments
of an ``associative_scan``), bounding backward residuals to one state per
segment instead of one per timestep.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.runtime import axis_size_compat
from repro.core.runtime import shard_map_compat as _shard_map
from repro.core.runtime import Runtime
from repro.core.topology import BATCH_AXES, SEQ_AXES
from repro.models.layers import (init_linear, init_rmsnorm, linear_apply,
                                 rmsnorm_apply)


# ---------------------------------------------------------------------------
# Scan machinery
# ---------------------------------------------------------------------------

def _assoc_combine(left, right):
    a_l, u_l = left
    a_r, u_r = right
    return a_l * a_r, u_l * a_r + u_r


def _assoc_fold(a, u, axis: int = 1):
    """Associative pair-fold of (decay, increment) along ``axis`` — the
    final state only, in 2× the tensor's traffic (vs log-n sweeps of an
    associative_scan).  Used by the summary pass of the chunked CP scan.
    """
    while a.shape[axis] > 1:
        n = a.shape[axis]
        if n % 2:
            # fold the odd tail into its neighbour first
            a_last = jnp.take(a, jnp.array([n - 1]), axis=axis)
            u_last = jnp.take(u, jnp.array([n - 1]), axis=axis)
            a_prev = jnp.take(a, jnp.array([n - 2]), axis=axis)
            u_prev = jnp.take(u, jnp.array([n - 2]), axis=axis)
            a2, u2 = _assoc_combine((a_prev, u_prev), (a_last, u_last))
            a = jnp.concatenate(
                [jax.lax.slice_in_dim(a, 0, n - 2, axis=axis), a2], axis)
            u = jnp.concatenate(
                [jax.lax.slice_in_dim(u, 0, n - 2, axis=axis), u2], axis)
            n -= 1
        even = jax.lax.slice_in_dim(a, 0, n, 2, axis=axis), \
            jax.lax.slice_in_dim(u, 0, n, 2, axis=axis)
        odd = jax.lax.slice_in_dim(a, 1, n, 2, axis=axis), \
            jax.lax.slice_in_dim(u, 1, n, 2, axis=axis)
        a, u = _assoc_combine(even, odd)
    return jnp.squeeze(a, axis), jnp.squeeze(u, axis)


def _segmented_scan(h0, seg_fn, xs, n_seg: int):
    """lax.scan over segments with rematerialized bodies.

    ``seg_fn(h, seg_xs) -> (h_next, y_seg)``; residual storage is one carry
    per segment boundary.
    """
    body = jax.checkpoint(seg_fn)
    h_fin, ys = lax.scan(body, h0, xs)
    return h_fin, ys


def _halo_exchange(x, halo: int, axes, n_ranks: int):
    """Bring the previous sequence shard's last ``halo`` tokens in front.

    x: (B, S_loc, C).  Rank 0 receives zeros (the causal pad).

    Implementation note: ``lax.ppermute`` flattens multi-axis names in *mesh*
    order (not listed order), so a combined-axis ring shift is unsafe; the
    halo is a few tokens, so an all_gather + dynamic pick is cheap & exact.
    """
    tail = x[:, -halo:]
    if n_ranks == 1:
        return jnp.concatenate([jnp.zeros_like(tail), x], axis=1)
    tails = lax.all_gather(tail, axes)               # (R, B, halo, C)
    r = _linear_rank(axes)
    prev = lax.dynamic_index_in_dim(tails, jnp.maximum(r - 1, 0), 0,
                                    keepdims=False)
    prev = jnp.where(r > 0, prev, jnp.zeros_like(prev))
    return jnp.concatenate([prev, x], axis=1)


def _causal_conv(x, w, b, halo_x):
    """Depthwise causal conv.  x: (B, S+K-1, C) pre-padded; w: (K, C)."""
    k = w.shape[0]
    s = x.shape[1] - (k - 1)
    y = jnp.zeros((x.shape[0], s, x.shape[2]), jnp.float32)
    for i in range(k):
        y = y + x[:, i:i + s].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (y + b.astype(jnp.float32)).astype(halo_x)


def _cross_rank_state(d_tot, h_fin, axes, n_ranks: int):
    """Exclusive prefix of (decay, state) pairs across sequence shards.

    d_tot/h_fin: local chunk decay & final state (from the h0=0 pass).
    Returns (h0, h_global_final): this rank's initial state
    ``h0 = sum_{r'<r} (prod_{r'<m<r} D_m) h_{r'}`` and the state after the
    full sequence (identical on every rank — the decode cache seed).
    """
    if n_ranks == 1:
        return jnp.zeros_like(h_fin), h_fin
    ds = lax.all_gather(d_tot, axes)       # (R, ...)
    hs = lax.all_gather(h_fin, axes)
    prefixes = [jnp.zeros_like(h_fin)]
    for r in range(n_ranks):
        prefixes.append(prefixes[-1] * ds[r] + hs[r])
    stacked = jnp.stack(prefixes[:-1])     # (R, ...)
    idx = _linear_rank(axes)
    h0 = lax.dynamic_index_in_dim(stacked, idx, axis=0, keepdims=False)
    return h0, prefixes[-1]


def _linear_rank(axes):
    idx = lax.axis_index(axes[0])
    for a in axes[1:]:
        idx = idx * axis_size_compat(a) + lax.axis_index(a)
    return idx


# ---------------------------------------------------------------------------
# Mamba1 (falcon-mamba)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Mamba1Dims:
    d_model: int
    d_inner: int
    d_state: int = 16
    d_conv: int = 4
    dt_rank: int = 0          # 0 => ceil(d_model / 16)
    seg: int = 64             # intra-chunk scan segment length

    def __post_init__(self):
        if self.dt_rank == 0:
            object.__setattr__(self, "dt_rank",
                               (self.d_model + 15) // 16)


def init_mamba1(key, m: Mamba1Dims):
    ks = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, m.d_state + 1, dtype=jnp.float32),
                 (m.d_inner, 1))
    return {
        "in_proj": init_linear(ks[0], m.d_model, 2 * m.d_inner),
        "conv_w": 0.1 * jax.random.normal(ks[1], (m.d_conv, m.d_inner)),
        "conv_b": jnp.zeros((m.d_inner,), jnp.float32),
        "x_proj": init_linear(ks[2], m.d_inner, m.dt_rank + 2 * m.d_state),
        "dt_proj": init_linear(ks[3], m.dt_rank, m.d_inner, bias=True),
        "A_log": jnp.log(a),
        "D": jnp.ones((m.d_inner,), jnp.float32),
        "out_proj": init_linear(ks[4], m.d_inner, m.d_model),
    }


def _mamba1_scan_local(delta, bmat, cmat, x_in, a_diag, h0, seg: int):
    """delta/x_in: (B,S,di); bmat/cmat: (B,S,N); a_diag: (di,N) (negative).

    Returns y (B,S,di) f32, h_fin (B,di,N), d_tot (B,di,N).
    """
    b, s, di = delta.shape
    n = bmat.shape[-1]
    seg = min(seg, s)
    n_seg = s // seg
    assert s % seg == 0, (s, seg)

    def seg_fn(h, xs):
        d_s, b_s, c_s, x_s = xs                     # (B,seg,...)
        a = jnp.exp(d_s[..., None] * a_diag)        # (B,seg,di,N)
        u = (d_s * x_s)[..., None] * b_s[:, :, None, :]
        a_cum, u_cum = lax.associative_scan(_assoc_combine, (a, u), axis=1)
        h_t = a_cum * h[:, None] + u_cum            # (B,seg,di,N)
        y = jnp.einsum("bsdn,bsn->bsd", h_t, c_s)
        return h_t[:, -1], y

    xs = tuple(x.reshape(b, n_seg, seg, *x.shape[2:]).swapaxes(0, 1)
               for x in (delta, bmat, cmat, x_in))
    h_fin, ys = _segmented_scan(h0, seg_fn, xs, n_seg)
    y = ys.swapaxes(0, 1).reshape(b, s, di)
    d_tot = jnp.exp(jnp.sum(delta, axis=1)[..., None] * a_diag)
    return y, h_fin, d_tot


def mamba1_apply(p, x, rt: Runtime, m: Mamba1Dims,
                 return_state: bool = False):
    """x: (B, S, d_model) seq-sharded -> same (+ final state for prefill)."""
    xz = linear_apply(p["in_proj"], x)
    x_in, z = jnp.split(xz, 2, axis=-1)

    n_ranks = rt.pc.sp

    def conv_local(x_in):
        xp = _halo_exchange(x_in, m.d_conv - 1, SEQ_AXES, n_ranks)
        return jax.nn.silu(_causal_conv(xp, p["conv_w"], p["conv_b"],
                                        x_in.dtype))

    spec = P(BATCH_AXES, SEQ_AXES, None)
    x_conv = _shard_map(conv_local, rt.mesh, (spec,), spec)(x_in)

    dbc = linear_apply(p["x_proj"], x_conv)
    dt = jax.nn.softplus(
        linear_apply(p["dt_proj"], dbc[..., :m.dt_rank]).astype(jnp.float32))
    bmat = dbc[..., m.dt_rank:m.dt_rank + m.d_state].astype(jnp.float32)
    cmat = dbc[..., m.dt_rank + m.d_state:].astype(jnp.float32)
    a_diag = -jnp.exp(p["A_log"])

    def scan_local(dt, bmat, cmat, x_conv):
        bsz = dt.shape[0]
        xf = x_conv.astype(jnp.float32)
        # ONE local scan from h0=0; the cross-rank initial state enters as
        # a closed-form affine correction (h_t is affine in h0 and the
        # cumulative decay exp(A·cumsum(Δ)) needs no scan) — half the scan
        # traffic of the two-pass formulation.
        y0, h_fin, d_tot = _mamba1_scan_local(dt, bmat, cmat, xf, a_diag,
                                              jnp.zeros((bsz, m.d_inner,
                                                         m.d_state),
                                                        jnp.float32), m.seg)
        if n_ranks == 1:
            return y0.astype(x_conv.dtype), h_fin
        h_init, h_last = _cross_rank_state(d_tot, h_fin, SEQ_AXES, n_ranks)
        cum = jnp.cumsum(dt, axis=1)                      # (B,S,di)
        # corr_t[d] = sum_n C_t[n] · h0[d,n] · exp(A[d,n]·cumΔ_t[d])
        decay = jnp.exp(cum[..., None] * a_diag)          # (B,S,di,N)
        corr = jnp.einsum("bsdn,bdn,bsn->bsd", decay, h_init, cmat)
        return (y0 + corr).astype(x_conv.dtype), h_last

    y, h_last = _shard_map(scan_local, rt.mesh, (spec,) * 4,
                           (spec, P(BATCH_AXES, None, None)))(
        dt, bmat, cmat, x_conv)
    y = y + x_conv * p["D"].astype(x_conv.dtype)
    y = y * jax.nn.silu(z)
    out = linear_apply(p["out_proj"], y)
    if return_state:
        return out, {"h": h_last, "conv": x_in[:, -(m.d_conv - 1):]}
    return out


def mamba1_decode(p, x, state, m: Mamba1Dims):
    """Single-token step.  x: (B, 1, d_model).

    state: {"h": (B, di, N) f32, "conv": (B, d_conv-1, di)}.
    Returns (y (B,1,d_model), new_state).
    """
    xz = linear_apply(p["in_proj"], x)
    x_in, z = jnp.split(xz, 2, axis=-1)
    conv_buf = jnp.concatenate([state["conv"], x_in], axis=1)
    x_conv = jax.nn.silu(_causal_conv(conv_buf, p["conv_w"], p["conv_b"],
                                      x_in.dtype))
    dbc = linear_apply(p["x_proj"], x_conv)
    dt = jax.nn.softplus(
        linear_apply(p["dt_proj"], dbc[..., :m.dt_rank]).astype(jnp.float32))
    bmat = dbc[..., m.dt_rank:m.dt_rank + m.d_state].astype(jnp.float32)
    cmat = dbc[..., m.dt_rank + m.d_state:].astype(jnp.float32)
    a_diag = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[:, 0, :, None] * a_diag)                 # (B,di,N)
    u = (dt[:, 0] * x_conv[:, 0].astype(jnp.float32))[..., None] \
        * bmat[:, 0, None, :]
    h = state["h"] * a + u
    y = jnp.einsum("bdn,bn->bd", h, cmat[:, 0])[:, None]
    y = y.astype(x.dtype) + x_conv * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    return linear_apply(p["out_proj"], y), {"h": h,
                                            "conv": conv_buf[:, 1:]}


# ---------------------------------------------------------------------------
# Mamba2 (zamba2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Mamba2Dims:
    d_model: int
    d_inner: int
    d_state: int = 64
    d_conv: int = 4
    head_dim: int = 64
    seg: int = 32

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.d_state


def init_mamba2(key, m: Mamba2Dims):
    ks = jax.random.split(key, 4)
    return {
        "in_proj": init_linear(
            ks[0], m.d_model,
            2 * m.d_inner + 2 * m.d_state + m.n_heads),
        "conv_w": 0.1 * jax.random.normal(ks[1], (m.d_conv, m.conv_dim)),
        "conv_b": jnp.zeros((m.conv_dim,), jnp.float32),
        "A_log": jnp.zeros((m.n_heads,), jnp.float32),
        "D": jnp.ones((m.n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((m.n_heads,), jnp.float32),
        "norm": init_rmsnorm(m.d_inner),
        "out_proj": init_linear(ks[2], m.d_inner, m.d_model),
    }


def _mamba2_scan_local(dt, bmat, cmat, x_h, a_head, h0, seg: int):
    """dt: (B,S,nh); bmat/cmat: (B,S,N); x_h: (B,S,nh,hd); a_head: (nh,).

    Returns y (B,S,nh,hd) f32, h_fin (B,nh,hd,N), d_tot (B,nh,1,1).
    """
    b, s, nh = dt.shape
    seg = min(seg, s)
    n_seg = s // seg

    def seg_fn(h, xs):
        d_s, b_s, c_s, x_s = xs
        a = jnp.exp(d_s * a_head)[..., None, None]          # (B,seg,nh,1,1)
        u = (d_s[..., None] * x_s)[..., None] \
            * b_s[:, :, None, None, :]                      # (B,seg,nh,hd,N)
        a_cum, u_cum = lax.associative_scan(_assoc_combine, (a, u), axis=1)
        h_t = a_cum * h[:, None] + u_cum
        y = jnp.einsum("bshdn,bsn->bshd", h_t, c_s)
        return h_t[:, -1], y

    xs = tuple(x.reshape(b, n_seg, seg, *x.shape[2:]).swapaxes(0, 1)
               for x in (dt, bmat, cmat, x_h))
    h_fin, ys = _segmented_scan(h0, seg_fn, xs, n_seg)
    y = ys.swapaxes(0, 1).reshape(b, s, *ys.shape[3:])
    d_tot = jnp.exp(jnp.sum(dt, axis=1) * a_head)[..., None, None]
    return y, h_fin, d_tot


def mamba2_apply(p, x, rt: Runtime, m: Mamba2Dims,
                 return_state: bool = False):
    """x: (B, S, d_model) seq-sharded -> same (+ final state for prefill)."""
    zxbcdt = linear_apply(p["in_proj"], x)
    z = zxbcdt[..., :m.d_inner]
    xbc_pre = zxbcdt[..., m.d_inner:m.d_inner + m.conv_dim]
    dt_raw = zxbcdt[..., m.d_inner + m.conv_dim:]

    n_ranks = rt.pc.sp
    spec3 = P(BATCH_AXES, SEQ_AXES, None)

    def conv_local(xbc):
        xp = _halo_exchange(xbc, m.d_conv - 1, SEQ_AXES, n_ranks)
        return jax.nn.silu(_causal_conv(xp, p["conv_w"], p["conv_b"],
                                        xbc.dtype))

    xbc = _shard_map(conv_local, rt.mesh, (spec3,), spec3)(xbc_pre)
    x_in = xbc[..., :m.d_inner]
    bmat = xbc[..., m.d_inner:m.d_inner + m.d_state].astype(jnp.float32)
    cmat = xbc[..., m.d_inner + m.d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    a_head = -jnp.exp(p["A_log"])

    def scan_local(dt, bmat, cmat, x_in):
        bsz, s_loc, _ = x_in.shape
        x_h = x_in.reshape(bsz, s_loc, m.n_heads,
                           m.head_dim).astype(jnp.float32)
        y0, h_fin, d_tot = _mamba2_scan_local(
            dt, bmat, cmat, x_h, a_head,
            jnp.zeros((bsz, m.n_heads, m.head_dim, m.d_state),
                      jnp.float32), m.seg)
        if n_ranks == 1:
            return (y0.reshape(bsz, s_loc, m.d_inner).astype(x_in.dtype),
                    h_fin)
        h_init, h_last = _cross_rank_state(d_tot, h_fin, SEQ_AXES, n_ranks)
        # scalar-per-head decay => the correction is one small einsum
        decay = jnp.exp(jnp.cumsum(dt, axis=1) * a_head)  # (B,S,nh)
        corr = jnp.einsum("bsh,bhdn,bsn->bshd", decay, h_init, cmat)
        y = y0 + corr
        return (y.reshape(bsz, s_loc, m.d_inner).astype(x_in.dtype), h_last)

    y, h_last = _shard_map(scan_local, rt.mesh, (spec3,) * 4,
                           (spec3, P(BATCH_AXES, None, None, None)))(
        dt, bmat, cmat, x_in)
    d_rep = jnp.repeat(p["D"], m.head_dim).astype(x_in.dtype)
    y = y + x_in * d_rep
    y = rmsnorm_apply(p["norm"], y * jax.nn.silu(z))
    out = linear_apply(p["out_proj"], y)
    if return_state:
        # conv state: last (K-1) pre-activation conv inputs, global order
        return out, {"h": h_last, "conv": xbc_pre[:, -(m.d_conv - 1):]}
    return out


def mamba2_decode(p, x, state, m: Mamba2Dims):
    """Single-token step.  state: {"h": (B,nh,hd,N), "conv": (B,K-1,convd)}."""
    zxbcdt = linear_apply(p["in_proj"], x)
    z = zxbcdt[..., :m.d_inner]
    xbc = zxbcdt[..., m.d_inner:m.d_inner + m.conv_dim]
    dt_raw = zxbcdt[..., m.d_inner + m.conv_dim:]
    conv_buf = jnp.concatenate([state["conv"], xbc], axis=1)
    xbc = jax.nn.silu(_causal_conv(conv_buf, p["conv_w"], p["conv_b"],
                                   x.dtype))
    x_in = xbc[..., :m.d_inner]
    bmat = xbc[..., m.d_inner:m.d_inner + m.d_state].astype(jnp.float32)
    cmat = xbc[..., m.d_inner + m.d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))[:, 0]  # (B,nh)
    a = jnp.exp(dt * -jnp.exp(p["A_log"]))[..., None, None]
    x_h = x_in[:, 0].reshape(x.shape[0], m.n_heads,
                             m.head_dim).astype(jnp.float32)
    u = (dt[..., None] * x_h)[..., None] * bmat[:, 0, None, None, :]
    h = state["h"] * a + u
    y = jnp.einsum("bhdn,bn->bhd", h, cmat[:, 0])
    y = y.reshape(x.shape[0], 1, m.d_inner).astype(x.dtype)
    y = y + x_in * jnp.repeat(p["D"], m.head_dim).astype(x.dtype)
    y = rmsnorm_apply(p["norm"], y * jax.nn.silu(z))
    return linear_apply(p["out_proj"], y), {"h": h, "conv": conv_buf[:, 1:]}
