"""Continuous-batching paged-KV serving engine.

The serving pillar next to training: a paged KV cache (fixed-size blocks,
free-list allocator, per-request block tables — ``paged_cache``), a
batched sampler (``sampling``), a request scheduler with admission /
eviction and chunked prefill (``scheduler``), and the engine that drives
jitted prefill-chunk / decode steps at bucketed shapes so new requests
join mid-stream without recompilation (``engine``).
"""
from repro.serve.engine import EngineConfig, ServeEngine
from repro.serve.paged_cache import (BlockAllocator, blocks_needed,
                                     init_paged_caches,
                                     paged_cache_shardings, window_flags)
from repro.serve.sampling import SamplingParams, sample_tokens
from repro.serve.scheduler import Request, Scheduler

__all__ = [
    "BlockAllocator", "EngineConfig", "Request", "SamplingParams",
    "Scheduler", "ServeEngine", "blocks_needed", "init_paged_caches",
    "paged_cache_shardings", "sample_tokens", "window_flags",
]
