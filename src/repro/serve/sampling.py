"""Batched token sampling: greedy / temperature / top-k / top-p with
per-request parameters and per-request PRNG streams.

One jitted call samples the whole decode batch: every request carries its
own ``(temperature, top_k, top_p)`` triple and its own key stream (base
key folded with the request id at admission, folded with the step index
per token), so restarts and slot reuse are reproducible.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration.

    ``temperature <= 0`` means greedy argmax (top-k/top-p ignored);
    ``top_k == 0`` disables the top-k filter; ``top_p >= 1`` disables the
    nucleus filter.  Filters compose: top-k first, then top-p over the
    *unfiltered* sorted mass (the usual serving semantics).
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0


def request_key(params: SamplingParams, rid: int) -> jax.Array:
    """The request's base PRNG stream: seed ⊕ request id."""
    return jax.random.fold_in(jax.random.PRNGKey(params.seed), rid)


def sample_tokens(logits, temps, top_ks, top_ps, keys, steps):
    """Sample one token per request.

    logits (B, V) fp32; temps (B,) fp32; top_ks (B,) int32; top_ps (B,)
    fp32; keys (B, 2) uint32 base streams; steps (B,) int32 per-request
    step indices (folded into the key so every position draws fresh).
    Returns (B,) int32.

    Ties at the top-k boundary keep every tied logit (harmless: the
    filter is a variance reducer, not an exact order statistic).
    """
    b, v = logits.shape
    logits = logits.astype(jnp.float32)
    temps = jnp.asarray(temps, jnp.float32)
    top_ks = jnp.asarray(top_ks, jnp.int32)
    top_ps = jnp.clip(jnp.asarray(top_ps, jnp.float32), 1e-6, 1.0)

    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / jnp.maximum(temps, 1e-6)[:, None]

    sorted_desc = jnp.sort(scaled, axis=-1)[:, ::-1]
    # top-k: keep logits >= the k-th largest (k == 0 disables)
    kth = jnp.take_along_axis(sorted_desc,
                              jnp.clip(top_ks - 1, 0, v - 1)[:, None],
                              axis=-1)
    keep_k = (top_ks[:, None] <= 0) | (scaled >= kth)
    # top-p: smallest sorted prefix with mass >= p (exclusive cumsum keeps
    # the argmax even for tiny p)
    probs_sorted = jax.nn.softmax(sorted_desc, axis=-1)
    cum_excl = jnp.cumsum(probs_sorted, axis=-1) - probs_sorted
    keep_sorted = cum_excl < top_ps[:, None]
    thresh_p = jnp.min(jnp.where(keep_sorted, sorted_desc, jnp.inf),
                       axis=-1)
    keep_p = scaled >= thresh_p[:, None]

    masked = jnp.where(keep_k & keep_p, scaled, -jnp.inf)
    step_keys = jax.vmap(jax.random.fold_in)(keys, steps)
    drawn = jax.vmap(jax.random.categorical)(step_keys, masked)
    return jnp.where(temps <= 0.0, greedy, drawn.astype(jnp.int32))
