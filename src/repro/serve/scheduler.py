"""Request lifecycle + admission control for the continuous-batching
engine.

A ``Request`` moves ``waiting -> prefill -> decode -> finished``.  The
``Scheduler`` owns the waiting queue, the fixed pool of engine slots, and
the block allocator: a request is admitted only when a slot is free AND
its *worst-case* footprint (``ceil((prompt + max_new) / page) `` blocks)
can be reserved, so a running request can never be starved of pages
mid-stream.  ``evict`` demotes a running request back to the head of the
waiting queue (its pages are released and its progress reset) — the
pressure valve for oversubscribed pools.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.serve.paged_cache import BlockAllocator, blocks_needed
from repro.serve.sampling import SamplingParams

WAITING, PREFILL, DECODE, FINISHED = "waiting", "prefill", "decode", \
    "finished"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                   # (S,) int32
    sampling: SamplingParams
    max_new_tokens: int = 16
    eos_id: int | None = None
    # -- engine state --
    state: str = WAITING
    slot: int = -1
    blocks: list = dataclasses.field(default_factory=list)
    prefilled: int = 0                   # prompt tokens already in cache
    out_tokens: list = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = 0.0                 # first generated token
    t_done: float = 0.0

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def total_len(self) -> int:
        return self.prompt_len + self.max_new_tokens


class Scheduler:
    """Admission / eviction over ``max_batch`` slots + the block pool."""

    def __init__(self, max_batch: int, allocator: BlockAllocator,
                 page_size: int, max_blocks_per_seq: int):
        self.max_batch = max_batch
        self.alloc = allocator
        self.page_size = page_size
        self.max_blocks_per_seq = max_blocks_per_seq
        self.waiting: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * max_batch

    # -- queries ------------------------------------------------------------

    def free_slot(self) -> int | None:
        for i, r in enumerate(self.slots):
            if r is None:
                return i
        return None

    def running(self, *states) -> list[Request]:
        states = states or (PREFILL, DECODE)
        return [r for r in self.slots if r is not None and r.state in states]

    def next_prefill(self) -> Request | None:
        for r in self.slots:
            if r is not None and r.state == PREFILL:
                return r
        return None

    def idle(self) -> bool:
        return not self.waiting and all(r is None for r in self.slots)

    # -- transitions --------------------------------------------------------

    def submit(self, req: Request) -> None:
        assert req.state == WAITING, req.state
        n = blocks_needed(req.total_len, self.page_size)
        if n > self.max_blocks_per_seq:
            raise ValueError(
                f"request {req.rid}: {req.total_len} tokens need {n} blocks"
                f" > max_blocks_per_seq={self.max_blocks_per_seq}")
        self.waiting.append(req)

    def admit(self) -> list[Request]:
        """Move waiting requests into free slots while blocks last (FIFO —
        no request starves behind a shorter latecomer)."""
        admitted = []
        while self.waiting:
            slot = self.free_slot()
            if slot is None:
                break
            req = self.waiting[0]
            blocks = self.alloc.alloc(
                blocks_needed(req.total_len, self.page_size))
            if blocks is None:
                break
            self.waiting.popleft()
            req.state, req.slot, req.blocks = PREFILL, slot, blocks
            req.prefilled = 0
            req.out_tokens = []
            self.slots[slot] = req
            admitted.append(req)
        return admitted

    def evict(self, req: Request) -> None:
        """Demote a running request to the waiting-queue head, releasing
        its pages (progress restarts from scratch on re-admission).
        Engine users must go through ``ServeEngine.evict``, which also
        clears the device-state slot and the hot-loop mirror."""
        assert req.state in (PREFILL, DECODE), req.state
        self._release(req)
        req.state = WAITING
        self.waiting.appendleft(req)

    def retire(self, req: Request) -> None:
        assert req.state in (PREFILL, DECODE), req.state
        self._release(req)
        req.state = FINISHED

    def _release(self, req: Request) -> None:
        self.alloc.free(req.blocks)
        self.slots[req.slot] = None
        req.blocks, req.slot, req.prefilled = [], -1, 0
