"""The continuous-batching serve engine.

One engine step interleaves (a) admission of waiting requests into free
slots, (b) one chunked-prefill call for the oldest admitted request, and
(c) one fused decode+sample step over every in-flight sequence — so a
long prompt never stalls the decode batch, and finished sequences retire
in-place for the next waiting request.

Shapes are bucketed: decode always runs at ``(max_batch, 1)`` with
inactive slots masked by ``lengths == -1``, prefill chunks are padded to
a power-of-two ladder, and caches are pre-sized to each request's
``prompt + max_new_tokens`` worst case at admission (block reservation) —
so after warmup **no jitted function ever retraces** (asserted by the
``decode_traces`` / ``prefill_traces`` counters, see tests).

The decode hot loop is sync-free: sampling is fused into the decode jit,
all per-slot state (lengths, last tokens, sampling params, PRNG streams,
output buffer, block tables) lives on device, and generated tokens are
fetched only when a request retires — one dispatch per token batch, no
per-step host↔device traffic (unless a request asked for EOS detection,
which needs the token values each step).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.models.decode import PagedLayout, decode_step, prefill_chunk
from repro.serve.paged_cache import (BlockAllocator, init_paged_caches,
                                     paged_cache_shardings, window_flags)
from repro.serve.sampling import SamplingParams, request_key, sample_tokens
from repro.serve.scheduler import DECODE, Request, Scheduler

MIN_BUCKET = 16


def _pow2(n: int, lo: int, hi: int | None = None) -> int:
    """Smallest power-of-two ≥ n, floored at lo, optionally capped at hi.
    The single bucket ladder shared by prefill chunks, decode views and
    warmup — one definition so jit cache keys can never drift apart."""
    b = lo
    while b < n:
        b *= 2
    return b if hi is None else min(b, hi)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Standalone engine geometry (``plan.serve_spec()`` derives one from
    the memory model; tests construct it directly)."""
    page_size: int = 16
    num_blocks: int = 64
    max_blocks_per_seq: int = 16
    max_batch: int = 4
    prefill_chunk: int = 64


class ServeEngine:
    """Continuous-batching engine over an ExecutionPlan + params.

    ``submit()`` enqueues prompts; ``step()`` advances the world by one
    scheduler tick; ``run()`` drains everything and returns per-request
    outputs with latency stats.  Dense/moe families (GQA full/sliding-
    window attention and absorbed MLA).
    """

    #: output-buffer width: requests may generate at most this many tokens
    MAX_NEW_CAP = 1024

    def __init__(self, plan, params, spec=None):
        self.plan, self.params = plan, params
        self.cfg, self.rt = plan.cfg, plan.rt
        assert self.cfg.family in ("dense", "moe"), self.cfg.family
        spec = spec or plan.serve_spec()
        assert spec is not None, "plan has no serve spec for this family"
        self.spec = spec

        pools = init_paged_caches(self.cfg, num_blocks=spec.num_blocks,
                                  page_size=spec.page_size,
                                  max_batch=spec.max_batch)
        sh = paged_cache_shardings(self.cfg, pools, plan.mesh)
        self._flags = window_flags(self.cfg, pools)
        self.has_window = any(jax.tree.leaves(self._flags))

        self.alloc = BlockAllocator(spec.num_blocks)
        self.sched = Scheduler(spec.max_batch, self.alloc, spec.page_size,
                               spec.max_blocks_per_seq)

        b = spec.max_batch
        # Device-resident per-slot state — the decode loop never reads it
        # back; slices are updated at admission/prefill boundaries only.
        self.st = {
            "pools": jax.device_put(pools, sh),
            "btabs": jnp.zeros((b, spec.max_blocks_per_seq), jnp.int32),
            "lengths": jnp.full((b,), -1, jnp.int32),
            "last": jnp.zeros((b,), jnp.int32),
            "steps": jnp.zeros((b,), jnp.int32),
            "out": jnp.zeros((b, self.MAX_NEW_CAP), jnp.int32),
            "temps": jnp.zeros((b,), jnp.float32),
            "top_ks": jnp.zeros((b,), jnp.int32),
            "top_ps": jnp.ones((b,), jnp.float32),
            "keys": jnp.zeros((b, 2), jnp.uint32),
        }

        self.requests: dict[int, Request] = {}
        self._decoding: list[Request] = []      # hot-loop mirror of DECODE
        self._next_rid = 0
        self.decode_traces = 0
        self.prefill_traces: dict[int, int] = {}
        self._prefill_jits: dict[int, object] = {}

        cfg, rt = self.cfg, self.rt
        page, nb, cap = spec.page_size, spec.num_blocks, self.MAX_NEW_CAP

        def _fused(st, nbv: int, do_sample: bool):
            """decode_step + sampling + bookkeeping, one dispatch.
            Serving weights are stationary: ``params`` is closed over, so
            the hot loop never re-flattens the parameter pytree.  ``nbv``
            (static) is the view bucket: only the first ``nbv`` block-table
            columns are gathered, so attention compute follows the longest
            *active* sequence instead of the worst case — the fixed-batch
            baseline cannot do this without re-tracing.  ``do_sample``
            (static) skips the sort/softmax filter stack entirely when
            every in-flight request is greedy (the engine checks per
            step), leaving a bare argmax in the hot loop."""
            self.decode_traces += 1
            active = st["lengths"] >= 0
            paged = PagedLayout(st["btabs"][:, :nbv], page, nb)
            logits, pools = decode_step(params, st["pools"],
                                        st["last"][:, None], st["lengths"],
                                        rt, cfg, paged)
            if do_sample:
                toks = sample_tokens(logits[:, 0], st["temps"],
                                     st["top_ks"], st["top_ps"],
                                     st["keys"], st["steps"])
            else:
                toks = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            toks = jnp.where(active, toks, st["last"])
            slot_i = jnp.arange(toks.shape[0])
            out = st["out"].at[
                slot_i, jnp.where(active, st["steps"], cap)].set(
                toks, mode="drop")
            inc = active.astype(jnp.int32)
            return {**st, "pools": pools, "last": toks, "out": out,
                    "lengths": st["lengths"] + inc,
                    "steps": st["steps"] + inc}

        def _start(st, logits, slot, plen):
            """First generated token after the last prefill chunk."""
            sl1 = lambda a: lax.dynamic_slice_in_dim(a, slot, 1)  # noqa
            tok = sample_tokens(logits, sl1(st["temps"]),
                                sl1(st["top_ks"]), sl1(st["top_ps"]),
                                lax.dynamic_slice_in_dim(st["keys"], slot,
                                                         1),
                                sl1(st["steps"]))
            return {**st,
                    "last": st["last"].at[slot].set(tok[0]),
                    "out": st["out"].at[slot, 0].set(tok[0]),
                    "lengths": st["lengths"].at[slot].set(plen),
                    "steps": st["steps"].at[slot].set(1)}

        self._fused = jax.jit(_fused, donate_argnums=(0,),
                              static_argnums=(1, 2))
        self._start = jax.jit(_start, donate_argnums=(0,))

    # -- request intake -----------------------------------------------------

    def submit(self, prompt, sampling: SamplingParams | None = None,
               max_new_tokens: int = 16, eos_id: int | None = None) -> int:
        assert max_new_tokens <= self.MAX_NEW_CAP, max_new_tokens
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid,
                      prompt=np.asarray(prompt, np.int32).reshape(-1),
                      sampling=sampling or SamplingParams(),
                      max_new_tokens=int(max_new_tokens), eos_id=eos_id)
        req.t_submit = time.perf_counter()
        self.sched.submit(req)
        self.requests[rid] = req
        return rid

    # -- jitted prefill per bucket -------------------------------------------

    def _bucket(self, n: int) -> int:
        return _pow2(n, MIN_BUCKET)

    def _prefill_fn(self, lc: int, nbv: int):
        key = (lc, nbv)
        if key in self._prefill_jits:
            return self._prefill_jits[key]
        cfg, rt, flags = self.cfg, self.rt, self._flags
        page, nb = self.spec.page_size, self.spec.num_blocks

        params = self.params

        def _pf(st, tokens, start, valid, slot):
            self.prefill_traces[key] = self.prefill_traces.get(key, 0) + 1
            # Ring-buffer (window) leaves carry a max_batch dim: slice this
            # request's row, prefill at B=1, splice back.  Paged pools are
            # shared and flow through whole; the gathered view is bucketed
            # to the first ``nbv`` block-table columns (enough for
            # ``start + valid``), so chunk attention never pays for the
            # worst-case sequence extent.
            pools = st["pools"]
            local = jax.tree.map(
                lambda leaf, w: lax.dynamic_slice_in_dim(leaf, slot, 1,
                                                         axis=1)
                if w else leaf, pools, flags)
            btab_row = lax.dynamic_slice_in_dim(st["btabs"], slot, 1)
            paged = PagedLayout(btab_row[:, :nbv], page, nb)
            logits, new_local = prefill_chunk(params, local, tokens[None],
                                              start, valid, rt, cfg, paged)
            merged = jax.tree.map(
                lambda old, new, w: lax.dynamic_update_slice_in_dim(
                    old, new, slot, axis=1) if w else new,
                pools, new_local, flags)
            return logits[:, 0], {**st, "pools": merged}

        f = jax.jit(_pf, donate_argnums=(0,))
        self._prefill_jits[key] = f
        return f

    def warmup(self, prompt_lens=(), max_new: int = 2) -> None:
        """Compile every decode view bucket (both the greedy and the
        sampling variant) and the prefill buckets the given prompt
        lengths hit, so a latency-sensitive caller pays tracing before
        opening the doors."""
        nbv = 4
        while True:
            nbv = _pow2(nbv, 4, self.spec.max_blocks_per_seq)
            # no active slot: a fused call is a harmless no-op compile
            self.st = self._fused(self.st, nbv, False)
            self.st = self._fused(self.st, nbv, True)
            if nbv >= self.spec.max_blocks_per_seq:
                break
            nbv *= 2
        lens = sorted({self._bucket(n) for n in prompt_lens} or
                      {MIN_BUCKET})
        rng = np.random.default_rng(0)
        for n in lens:
            self.submit(rng.integers(0, self.cfg.vocab, size=n),
                        SamplingParams(), max_new_tokens=max_new)
        self.run()
        self.requests.clear()

    # -- one scheduler tick --------------------------------------------------

    def step(self) -> list[Request]:
        """Admit → one prefill chunk → one fused decode step.  Returns
        the requests that finished during this tick."""
        finished: list[Request] = []
        if self.sched.waiting:
            for req in self.sched.admit():
                self._on_admit(req)
        pf = self.sched.next_prefill()
        if pf is not None:
            done = self._prefill_step(pf)
            if done is not None:
                finished.append(done)
        if self._decoding:
            finished.extend(self._decode_step_all())
        return finished

    def _on_admit(self, req: Request) -> None:
        s, st = req.slot, self.st
        row = np.zeros((self.spec.max_blocks_per_seq,), np.int32)
        row[:len(req.blocks)] = req.blocks
        sp = req.sampling
        st["btabs"] = st["btabs"].at[s].set(jnp.asarray(row))
        st["lengths"] = st["lengths"].at[s].set(-1)
        st["steps"] = st["steps"].at[s].set(0)
        st["temps"] = st["temps"].at[s].set(sp.temperature)
        st["top_ks"] = st["top_ks"].at[s].set(sp.top_k)
        st["top_ps"] = st["top_ps"].at[s].set(sp.top_p)
        st["keys"] = st["keys"].at[s].set(request_key(sp, req.rid))

    def _prefill_step(self, req: Request) -> Request | None:
        s = req.slot
        remaining = req.prompt_len - req.prefilled
        if self.has_window:
            # Sliding-window layers: chunk-local banded attention is exact
            # only when the chunk covers the whole prompt (see
            # models/decode.py::prefill_chunk).
            assert req.prefilled == 0
            chunk = remaining
        else:
            chunk = min(self.spec.prefill_chunk, remaining)
        lc = self._bucket(chunk)
        need_blocks = -(-(req.prefilled + chunk) // self.spec.page_size)
        nbv = _pow2(need_blocks, 4, self.spec.max_blocks_per_seq)
        tokens = np.zeros((lc,), np.int32)
        tokens[:chunk] = req.prompt[req.prefilled:req.prefilled + chunk]
        logits, self.st = self._prefill_fn(lc, nbv)(
            self.st, jnp.asarray(tokens),
            jnp.int32(req.prefilled), jnp.int32(chunk), jnp.int32(s))
        req.prefilled += chunk
        if req.prefilled < req.prompt_len:
            return None
        # Prompt complete: its last logits seed the first generated token.
        self.st = self._start(self.st, logits, jnp.int32(s),
                              jnp.int32(req.prompt_len))
        req.t_first = time.perf_counter()
        req.state = DECODE
        req.out_tokens = [None]          # host mirror: count only
        self._decoding.append(req)
        if req.eos_id is not None and \
                int(np.asarray(self.st["last"][s])) == req.eos_id:
            return self._retire(req, s)  # EOS as the very first token
        if self._done(req):
            return self._retire(req, s)
        return None

    def _view_bucket(self) -> int:
        """Smallest power-of-two block count covering every active
        sequence's next write position."""
        need = max(r.prompt_len + len(r.out_tokens) for r in self._decoding)
        need_blocks = -(-(need + 1) // self.spec.page_size)
        return _pow2(need_blocks, 4, self.spec.max_blocks_per_seq)

    def _decode_step_all(self) -> list[Request]:
        do_sample = any(r.sampling.temperature > 0 for r in self._decoding)
        self.st = self._fused(self.st, self._view_bucket(), do_sample)
        eos_toks = None
        if any(r.eos_id is not None for r in self._decoding):
            eos_toks = np.asarray(self.st["last"])     # forces a sync
        finished = []
        for req in list(self._decoding):
            s = req.slot
            req.out_tokens.append(None)
            if eos_toks is not None and req.eos_id is not None and \
                    int(eos_toks[s]) == req.eos_id:
                finished.append(self._retire(req, s))
            elif self._done(req):
                finished.append(self._retire(req, s))
        return finished

    def _done(self, req: Request) -> bool:
        return len(req.out_tokens) >= req.max_new_tokens

    def evict(self, rid: int) -> None:
        """Demote a running request back to the waiting-queue head: pages
        released, progress reset, slot masked out of the decode batch.
        The engine-level pressure valve — use this, not
        ``sched.evict()`` directly, so the device state and the hot-loop
        mirror stay in sync with the scheduler."""
        req = self.requests[rid]
        slot = req.slot
        self.sched.evict(req)
        if req in self._decoding:
            self._decoding.remove(req)
        req.out_tokens = []
        if slot >= 0:
            self.st["lengths"] = self.st["lengths"].at[slot].set(-1)

    def _retire(self, req: Request, slot: int) -> Request:
        n = len(req.out_tokens)
        req.out_tokens = [int(t) for t in
                          np.asarray(self.st["out"][slot, :n])]
        req.t_done = time.perf_counter()
        self.sched.retire(req)
        if req in self._decoding:
            self._decoding.remove(req)
        self.st["lengths"] = self.st["lengths"].at[slot].set(-1)
        return req

    # -- drain ---------------------------------------------------------------

    def run(self, max_steps: int = 100_000) -> dict:
        """Drain all submitted requests.  Returns
        ``{"requests": {rid: {...}}, "wall_s", "generated",
        "tokens_per_s"}`` covering exactly the requests that finished
        during *this* call (an engine serves many batches; earlier runs'
        outputs never leak into later stats) — latency is submit→done
        (queueing included: that is the continuous-batching headline)."""
        t0 = time.perf_counter()
        steps = 0
        drained: list[Request] = []
        while not self.sched.idle():
            drained.extend(self.step())
            steps += 1
            if steps >= max_steps:
                raise RuntimeError(f"engine did not drain in {steps} steps")
        wall = time.perf_counter() - t0
        out, generated = {}, 0
        for req in drained:
            generated += len(req.out_tokens)
            out[req.rid] = {"tokens": list(req.out_tokens),
                            "latency_s": req.t_done - req.t_submit,
                            "first_token_s": req.t_first - req.t_submit}
        return {"requests": out, "wall_s": wall, "generated": generated,
                "engine_steps": steps,
                "tokens_per_s": generated / max(wall, 1e-9)}
