"""Paged KV-cache pools + the host-side free-list block allocator.

Full-attention K/V (and the MLA latent) live in fixed-size block pools
``(layers, num_blocks, page_size, ...)`` shared by every request; a
request owns an ordered list of physical blocks recorded in its block
table row.  Sliding-window layers keep per-slot ring buffers
``(layers, max_batch, window, ...)`` — they are already O(window) and a
ring write composes with paging for free (see ``models/decode.py``).

Sharding mirrors the contiguous ``kv_cache_spec`` layout: the S-carrying
block axis is sharded over the context axes ``(outer, inner)`` (each
context rank owns a subset of physical pages) and KV heads over ``head``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.topology import AXIS_HP, AXIS_INNER, AXIS_OUTER


def blocks_needed(n_tokens: int, page_size: int) -> int:
    return -(-n_tokens // page_size)


def paged_kv_spec() -> P:
    """PartitionSpec of a (layers, num_blocks, page, H, d) block pool."""
    return P(None, (AXIS_OUTER, AXIS_INNER), None, AXIS_HP, None)


def paged_latent_spec() -> P:
    """PartitionSpec of a (layers, num_blocks, page, dim) MLA latent pool."""
    return P(None, (AXIS_OUTER, AXIS_INNER), None, None)


def window_ring_spec(batch_axes=()) -> P:
    """PartitionSpec of a (layers, max_batch, window, H, d) ring buffer."""
    return P(None, batch_axes, (AXIS_OUTER, AXIS_INNER), AXIS_HP, None)


def init_paged_caches(cfg, *, num_blocks: int, page_size: int,
                      max_batch: int):
    """Zero block pools mirroring ``init_caches``'s stacked structure so
    ``decode_step``/``prefill_chunk`` scan over layers unchanged.
    Dense/moe families only (the engine's scope)."""
    assert cfg.family in ("dense", "moe"), cfg.family
    dt = cfg.compute_dtype
    if cfg.mla is not None:
        m = cfg.mla
        n = cfg.num_layers
        return {"blocks": [{
            "c": jnp.zeros((n, num_blocks, page_size, m.kv_lora), dt),
            "rope": jnp.zeros((n, num_blocks, page_size, m.d_rope), dt)}]}
    period = cfg.period
    groups = cfg.num_layers // period
    caches = []
    for slot in range(period):
        kind = cfg.attn_kind(slot)
        if kind.window is not None:
            shp = (groups, max_batch, kind.window, cfg.n_kv_heads, cfg.hd)
        else:
            shp = (groups, num_blocks, page_size, cfg.n_kv_heads, cfg.hd)
        caches.append({"k": jnp.zeros(shp, dt), "v": jnp.zeros(shp, dt)})
    return {"blocks": caches}


def window_flags(cfg, caches):
    """Pytree of bools matching ``init_paged_caches`` output: True for
    per-slot ring-buffer leaves (which carry a max_batch dim the engine
    must slice per request during prefill)."""
    def flag(slot_cache, is_window: bool):
        return jax.tree.map(lambda _: is_window, slot_cache)

    if cfg.mla is not None:
        return {"blocks": [flag(caches["blocks"][0], False)]}
    return {"blocks": [
        flag(c, cfg.attn_kind(slot).window is not None)
        for slot, c in enumerate(caches["blocks"])]}


def paged_cache_shardings(cfg, caches, mesh, batch_axes=()):
    """NamedSharding pytree matching ``init_paged_caches`` output."""
    flags = window_flags(cfg, caches)

    def spec_for(leaf, is_window: bool):
        if is_window:
            return window_ring_spec(batch_axes)
        if leaf.ndim == 5:
            return paged_kv_spec()
        return paged_latent_spec()

    return jax.tree.map(
        lambda leaf, w: NamedSharding(mesh, spec_for(leaf, w)),
        caches, flags)


class BlockAllocator:
    """Host-side free-list allocator over the physical block pool.

    Blocks are plain ints < num_blocks.  ``alloc`` is all-or-nothing (a
    request's worst-case footprint is reserved at admission, so the
    scheduler never deadlocks mid-stream); ``free`` returns a retired
    request's blocks.  Double-free and foreign-block frees raise.
    """

    def __init__(self, num_blocks: int):
        assert num_blocks > 0
        self.num_blocks = num_blocks
        self._free = list(range(num_blocks - 1, -1, -1))
        self._held: set[int] = set()

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        """n physical blocks, or None if the pool can't satisfy them."""
        if n < 0 or n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        self._held.update(out)
        return out

    def free(self, blocks) -> None:
        for blk in blocks:
            if blk not in self._held:
                raise ValueError(f"double/foreign free of block {blk}")
            self._held.discard(blk)
            self._free.append(blk)
