"""Packed-document training: data pipeline, masking contract, kernels.

Distributed packed-vs-unpacked parity (ring + Ulysses, Pallas-asserted)
lives in tests/_dist_checks.py::check_packed_parity; these are the
single-process pieces: PackedLM unit behaviour, the q_doc_start oracle
contract (doc-masked attention == per-document independent attention),
ref-vs-Pallas parity with document boundaries that straddle block edges
and ring-step edges, and the plan/cost-model packing term.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data.pipeline import DataConfig, PackedLM
from repro.kernels import ops, ref
from repro.kernels.ref import BandMask


def err(a, b):
    return float(np.abs(np.asarray(a, np.float64)
                        - np.asarray(b, np.float64)).max())


def doc_table(bounds, length):
    """Per-slot doc-start table for documents starting at ``bounds``."""
    out = np.zeros(length, np.int32)
    for i, s in enumerate(bounds):
        e = bounds[i + 1] if i + 1 < len(bounds) else length
        out[s:e] = s
    return out


def rand_qkv(rng, b, l, h, hkv, d):
    q = jnp.asarray(rng.standard_normal((b, l, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, l, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, l, hkv, d)), jnp.float32)
    return q, k, v


# ---------------------------------------------------------------------------
# PackedLM
# ---------------------------------------------------------------------------

class TestPackedLM:
    CFG = DataConfig(vocab=97, seq_len=64, global_batch=4, cp=2,
                     zigzag=True, doc_len_range=(8, 40))

    def test_deterministic(self):
        a = PackedLM(self.CFG).batch(3)
        b = PackedLM(self.CFG).batch(3)
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])
        c = PackedLM(self.CFG).batch(4)
        assert any((a[k] != c[k]).any() for k in a)

    def test_boundary_table_and_segments(self):
        data = PackedLM(self.CFG)
        bounds = data.boundaries(0)
        segs = data.segments(0)
        assert len(bounds) == self.CFG.global_batch
        for bi, docs in enumerate(bounds):
            assert docs[0][0] == 0
            end = 0
            for di, (s0, l) in enumerate(docs):
                assert s0 == end, "documents must be contiguous"
                assert (segs[bi, s0:s0 + l] == di).all()
                end = s0 + l
            assert end <= self.CFG.seq_len
            assert (segs[bi, end:] == -1).all()     # pad tail

    def test_labels_positions_doc_start(self):
        cfg = dataclasses.replace(self.CFG, cp=1, zigzag=False)
        data = PackedLM(cfg)
        batch = data.batch(0)
        tokens, labels = batch["tokens"], batch["labels"]
        positions, doc_start = batch["positions"], batch["doc_start"]
        for bi, docs in enumerate(data.boundaries(0)):
            for s0, l in docs:
                # labels are next-token within the doc; last label is -1
                np.testing.assert_array_equal(
                    labels[bi, s0:s0 + l - 1], tokens[bi, s0 + 1:s0 + l])
                assert labels[bi, s0 + l - 1] == -1
                np.testing.assert_array_equal(
                    positions[bi, s0:s0 + l], np.arange(l))
                assert (doc_start[bi, s0:s0 + l] == s0).all()
            end = docs[-1][0] + docs[-1][1]
            assert (labels[bi, end:] == -1).all()
            assert (doc_start[bi, end:] == end).all()
        # doc content is placement-independent: same doc ids -> same bytes
        # (content rng is seeded by (seed, step, doc id), not position)
        assert (tokens >= 0).all() and (tokens < cfg.vocab).all()

    def test_zigzag_layout_matches_synthetic_perm(self):
        from repro.core.zigzag import zigzag_indices
        cfg = self.CFG
        logical = PackedLM(dataclasses.replace(cfg, cp=1, zigzag=False))
        physical = PackedLM(cfg)
        perm = zigzag_indices(cfg.seq_len, cfg.cp)
        a, b = logical.batch(0), physical.batch(0)
        for k in a:
            np.testing.assert_array_equal(a[k][:, perm], b[k])

    def test_accum_split(self):
        cfg = dataclasses.replace(self.CFG, grad_accum=2)
        batch = PackedLM(cfg).batch(0)
        assert batch["doc_start"].shape == (2, 2, cfg.seq_len)


# ---------------------------------------------------------------------------
# Masking contract: doc-masked attention == independent documents
# ---------------------------------------------------------------------------

class TestDocMaskOracle:
    def test_equals_independent_docs(self):
        rng = np.random.default_rng(0)
        B, L, H, HKV, D = 2, 96, 4, 2, 16
        q, k, v = rand_qkv(rng, B, L, H, HKV, D)
        bounds = [[0, 37, 70], [0, 50]]
        doc = jnp.asarray(np.stack([doc_table(b, L) for b in bounds]))
        o_ref, _ = ref.attention_ref(q, k, v, causal=True, q_doc_start=doc)
        for b in range(B):
            for i, s in enumerate(bounds[b]):
                e = bounds[b][i + 1] if i + 1 < len(bounds[b]) else L
                o_doc, _ = ref.attention_ref(
                    q[b:b + 1, s:e], k[b:b + 1, s:e], v[b:b + 1, s:e],
                    causal=True)
                assert err(o_ref[b:b + 1, s:e], o_doc) < 1e-6, (b, s)

    def test_requires_causal(self):
        rng = np.random.default_rng(0)
        q, k, v = rand_qkv(rng, 1, 16, 2, 2, 8)
        doc = jnp.zeros((1, 16), jnp.int32)
        with pytest.raises(ValueError):
            ref.attention_ref(q, k, v, causal=False, q_doc_start=doc)
        with pytest.raises(ValueError):
            ops.flash_fwd_chunk(q, k, v, causal=False, q_doc_start=doc)

    def test_chunked_matches_dense(self):
        rng = np.random.default_rng(1)
        q, k, v = rand_qkv(rng, 1, 96, 2, 2, 8)
        doc = jnp.asarray(doc_table([0, 41], 96))[None]
        o_a, l_a = ref.attention_ref(q, k, v, causal=True, q_doc_start=doc)
        o_b, l_b = ref.attention_ref_chunked(q, k, v, causal=True,
                                             q_doc_start=doc, q_chunk=32)
        assert err(o_a, o_b) < 1e-6 and err(l_a, l_b) < 1e-6


# ---------------------------------------------------------------------------
# Pallas parity: boundaries straddling block and ring-step edges
# ---------------------------------------------------------------------------

class TestPallasDocParity:
    def test_fwd_bwd_block_straddle(self):
        """GQA fwd + bwd with doc boundaries (37, 50, 70) that straddle
        the 32-blocks — exercises the folded dk/dv grid with the doc
        operand."""
        rng = np.random.default_rng(2)
        B, L, H, HKV, D = 2, 96, 4, 2, 16
        q, k, v = rand_qkv(rng, B, L, H, HKV, D)
        doc = jnp.asarray(np.stack([doc_table([0, 37, 70], L),
                                    doc_table([0, 50], L)]))
        o_r, l_r = ref.attention_ref(q, k, v, causal=True, q_doc_start=doc)
        o_p, l_p = ops.flash_fwd_chunk(q, k, v, causal=True,
                                       q_doc_start=doc,
                                       impl="pallas_interpret",
                                       block_q=32, block_k=32)
        assert err(o_p, o_r) < 1e-5 and err(l_p, l_r) < 1e-5
        do = jnp.asarray(rng.standard_normal(o_r.shape), jnp.float32)
        g_r = ref.attention_bwd_ref(q, k, v, o_r, l_r, do, causal=True,
                                    q_doc_start=doc)
        g_p = ops.flash_bwd_chunk(q, k, v, o_r, l_r, do, causal=True,
                                  q_doc_start=doc, impl="pallas_interpret",
                                  block_q=32, block_k=32)
        for a, b in zip(g_p, g_r):
            assert err(a, b) < 1e-5

    @pytest.mark.parametrize("i,j", [(1, 0), (1, 1), (0, 1)])
    def test_zigzag_ring_step(self, i, j):
        """One ring step (j<i full, j=i diagonal, j>i half) with a doc
        boundary inside the local chunk: the stationary doc table + the
        per-step band must agree with the oracle."""
        from repro.core.zigzag import zigzag_indices
        rng = np.random.default_rng(3)
        B, L, H, HKV, D, cp = 1, 64, 2, 1, 8, 2
        q, k, v = rand_qkv(rng, B, L, H, HKV, D)
        doc_log = doc_table([0, 27, 45], L)[None]
        perm = zigzag_indices(L, cp)
        qz, kz, vz = q[:, perm], k[:, perm], v[:, perm]
        docz = jnp.asarray(doc_log[:, perm])
        s_loc = L // cp
        qi = qz[:, i * s_loc:(i + 1) * s_loc]
        di = docz[:, i * s_loc:(i + 1) * s_loc]
        kj = kz[:, j * s_loc:(j + 1) * s_loc]
        vj = vz[:, j * s_loc:(j + 1) * s_loc]
        band = BandMask.zigzag(jnp.int32(i), jnp.int32(j), s_loc // 2, cp)
        o_r, l_r = ref.attention_ref(qi, kj, vj, causal=True, band=band,
                                     q_doc_start=di)
        o_p, l_p = ops.flash_fwd_chunk(qi, kj, vj, causal=True, band=band,
                                       q_doc_start=di,
                                       impl="pallas_interpret",
                                       block_q=16, block_k=16)
        assert err(o_p, o_r) < 1e-5 and err(l_p, l_r) < 1e-5

    def test_window_composes_with_doc(self):
        """Sliding window + packed docs: both lower bounds apply (gemma-
        style local layers under packing)."""
        rng = np.random.default_rng(6)
        q, k, v = rand_qkv(rng, 1, 96, 2, 2, 8)
        doc = jnp.asarray(doc_table([0, 41], 96))[None]
        kw = dict(causal=True, window=24, q_doc_start=doc)
        o_r, l_r = ref.attention_ref(q, k, v, **kw)
        o_p, l_p = ops.flash_fwd_chunk(q, k, v, impl="pallas_interpret",
                                       block_q=32, block_k=32, **kw)
        assert err(o_p, o_r) < 1e-5 and err(l_p, l_r) < 1e-5
        # window-only rows differ from doc∧window rows somewhere
        o_w, _ = ref.attention_ref(q, k, v, causal=True, window=24)
        assert err(o_w, o_r) > 1e-3

    def test_doc_skip_identity(self):
        """Skipping cross-document blocks never changes numerics — only
        which grid steps run."""
        rng = np.random.default_rng(4)
        q, k, v = rand_qkv(rng, 1, 128, 2, 2, 16)
        doc = jnp.asarray(doc_table([0, 33, 66, 99], 128))[None]
        kw = dict(causal=True, q_doc_start=doc, impl="pallas_interpret",
                  block_q=32, block_k=32)
        o_a, l_a = ops.flash_fwd_chunk(q, k, v, doc_skip=True, **kw)
        o_b, l_b = ops.flash_fwd_chunk(q, k, v, doc_skip=False, **kw)
        assert err(o_a, o_b) == 0.0 and err(l_a, l_b) == 0.0

    def test_flash_attention_packed_grad(self):
        """Differentiable packed path (custom_vjp with the int doc table)
        matches the ref-path gradients."""
        rng = np.random.default_rng(5)
        q, k, v = rand_qkv(rng, 1, 64, 2, 2, 16)
        doc = jnp.asarray(doc_table([0, 21, 47], 64))[None]
        w = jnp.asarray(rng.standard_normal(q.shape), jnp.float32)

        def loss(impl):
            def f(q, k, v):
                out = ops.flash_attention(q, k, v, causal=True,
                                          q_doc_start=doc, impl=impl,
                                          block_q=32, block_k=32)
                return (out * w).sum()
            return jax.grad(f, argnums=(0, 1, 2))(q, k, v)

        for a, b in zip(loss("pallas_interpret"), loss("ref")):
            assert err(a, b) < 1e-5


# ---------------------------------------------------------------------------
# Plan + cost-model packing term
# ---------------------------------------------------------------------------

class TestPackedPlan:
    def _plan(self, **kw):
        from repro.configs import get_reduced
        from repro.core.plan import build_plan
        return build_plan(get_reduced("qwen3-1.7b"),
                          devices=jax.devices()[:1], impl="ref", **kw)

    def test_batch_shardings_and_source(self):
        plan = self._plan(seq_len=64, global_batch=4, packed=True,
                          mean_doc_len=16)
        assert "doc_start" in plan.batch_shardings("train")
        assert "doc_start" not in self._plan(
            seq_len=64, global_batch=4).batch_shardings("train")
        assert isinstance(plan.data_source(64, 4), PackedLM)
        assert abs(plan.packing_frac - 0.25) < 1e-9
        batch = plan.data_source(64, 4).batch(0)
        assert set(batch) == set(plan.batch_shardings("train"))

    def test_doc_len_range_clamped_to_seq(self):
        """A plan tuned at a longer sequence reused at a shorter one must
        not produce an infeasible document-length range."""
        plan = self._plan(seq_len=64, global_batch=4, packed=True,
                          mean_doc_len=4096)
        src = plan.data_source(64, 4)
        lo, hi = src._range
        assert 2 <= lo <= hi <= 64, (lo, hi)

    def test_grad_accum_token_weighted(self):
        """Packed bins carry unequal valid-token counts, so accumulated
        microbatches must be token-weighted: the accum=2 step must match
        the flat accum=1 step on the same global batch (the equal-count
        mean would skew toward sparsely filled bins)."""
        from repro.train.optimizer import init_opt_state
        from repro.train.train_step import jit_train_step
        from repro.models.model import init_params

        results = {}
        for accum in (1, 2):
            plan = self._plan(seq_len=64, global_batch=4, packed=True,
                              mean_doc_len=16, grad_accum=accum)
            data = plan.data_source(64, 4, doc_len_range=(6, 50))
            batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
            params = init_params(plan.cfg, jax.random.PRNGKey(0))
            opt = init_opt_state(params)
            with plan.mesh:
                step, _, _ = jit_train_step(plan, params, donate=False)
                p2, _, m = step(params, opt, batch)
            results[accum] = (jax.device_get(p2), float(m["loss"]),
                              float(m["n_tokens"]))
        # identical documents land in both layouts (content keys on doc
        # id), with genuinely unequal per-microbatch token counts
        assert results[1][2] == results[2][2]
        assert abs(results[1][1] - results[2][1]) < 1e-6
        for a, b in zip(jax.tree.leaves(results[1][0]),
                        jax.tree.leaves(results[2][0])):
            assert err(a, b) < 1e-6

    def test_packed_rejects_ssm(self):
        from repro.configs import get_reduced
        from repro.core.plan import build_plan
        with pytest.raises(AssertionError):
            build_plan(get_reduced("falcon-mamba-7b"),
                       devices=jax.devices()[:1], impl="ref", packed=True)

    def test_cost_model_packing_term(self):
        from repro.analysis.cost import (AttnCase, attn_flops_per_device,
                                         train_step_time)
        # 1M tokens on 64-way SP is compute-bound — packing must show up
        # in the modelled attention seconds, not just the FLOP count.
        base = AttnCase(s=1 << 20, sp=64, hp=8, w=4,
                        placement="context_first")
        packed = dataclasses.replace(base, packing=0.25)
        assert attn_flops_per_device(packed) == \
            pytest.approx(attn_flops_per_device(base) * 0.25)
        t_b = train_step_time(base)
        t_p = train_step_time(packed)
        assert t_p["attn_s"] < t_b["attn_s"]
        assert t_p["linear_s"] == t_b["linear_s"]
        # comm-bound corner: packing cannot make the step *slower*
        small = AttnCase(s=4096, sp=8, hp=2)
        assert train_step_time(
            dataclasses.replace(small, packing=0.25))["total_s"] \
            <= train_step_time(small)["total_s"]
        # from_plan picks the term up from the ExecutionPlan
        plan = self._plan(seq_len=4096, global_batch=4, packed=True,
                          mean_doc_len=1024)
        assert AttnCase.from_plan(plan).packing == \
            pytest.approx(plan.packing_frac)

    def test_tuner_scores_packing(self):
        from repro.configs import get_config
        from repro.tune.space import enumerate_space
        from repro.tune.tuner import score_candidate
        cfg = get_config("qwen3-1.7b")       # full dims: compute-bound
        cands = enumerate_space(cfg, num_devices=32, seq_len=131072,
                                global_batch=32, memory_budget_gb=16.0)
        assert cands
        # dp-heavy point: small sp => per-ring-step compute dominates the
        # KV hop, so the packing term reaches the modelled wall seconds
        c = max(cands, key=lambda c: (c.pc.dp, c.pc.cp))
        kw = dict(seq_len=131072, global_batch=32)
        dense = score_candidate(cfg, c, **kw)
        packed = score_candidate(cfg, c, packing=0.25, **kw)
        assert packed.terms["attn_s"] < dense.terms["attn_s"]
        assert packed.score_s < dense.score_s
