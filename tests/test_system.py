"""End-to-end system behaviour: trainer loop learns, resumes after a
simulated failure, and the launch surface is importable & coherent."""
import tempfile

import numpy as np
import jax
import pytest

from repro.configs import get_reduced
from repro.core.plan import build_plan
from repro.core.topology import ParallelConfig
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def _mk(cfg, d, steps, ckpt_every=10, grad_accum=1):
    plan = build_plan(cfg, opt=OptConfig(lr=3e-3, warmup_steps=5,
                                         total_steps=steps),
                      devices=jax.devices()[:1], grad_accum=grad_accum,
                      seq_len=64, global_batch=8)
    return Trainer(plan, plan.data_config(64, 8),
                   TrainerConfig(num_steps=steps, ckpt_dir=d,
                                 ckpt_every=ckpt_every, log_every=1000))


@pytest.mark.slow
def test_train_loss_decreases_and_resumes():
    cfg = get_reduced("qwen3-1.7b")
    with tempfile.TemporaryDirectory() as d:
        tr = _mk(cfg, d, steps=40)
        losses = tr.run()
        assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])
        assert all(np.isfinite(losses))
        # crash-and-resume: a fresh Trainer picks up the latest checkpoint
        tr2 = _mk(cfg, d, steps=42)
        assert tr2.start_step == 40
        more = tr2.run()
        assert len(more) == 2
        assert more[-1] < losses[0]


@pytest.mark.slow
def test_straggler_monitor_integrated():
    cfg = get_reduced("olmo-1b")
    with tempfile.TemporaryDirectory() as d:
        tr = _mk(cfg, d, steps=12, ckpt_every=100)
        tr.run()
        rep = tr.monitor.report()
        assert rep["steps"] == 12
        assert rep["median_s"] > 0


@pytest.mark.slow
def test_trainer_with_grad_accum_learns():
    """The microbatched trainer loop (accum=2, (2, 4, S) batches) still
    reduces the loss end to end."""
    cfg = get_reduced("qwen3-1.7b")
    with tempfile.TemporaryDirectory() as d:
        tr = _mk(cfg, d, steps=30, ckpt_every=100, grad_accum=2)
        assert tr.data.batch(0)["tokens"].shape == (2, 4, 64)
        losses = tr.run()
        assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])
        assert all(np.isfinite(losses))


def test_production_mesh_shapes():
    """Refine logic on a fake 512-device array (the real
    make_production_mesh needs 512 initialized devices — dry-run only)."""
    import numpy as onp
    from jax.sharding import Mesh
    from repro.core.topology import refine_mesh

    class FakeDev:
        def __init__(self, i):
            self.id = i

        def __repr__(self):
            return f"d{self.id}"

    devs = onp.array([FakeDev(i) for i in range(512)])
    base = Mesh(devs.reshape(2, 16, 16), ("pod", "data", "model"))
    pc = ParallelConfig(dp=16, hp=8, cp_outer=1, cp_inner=2, pods=2,
                        placement="head_first")
    mesh = refine_mesh(base, pc)
    assert mesh.axis_names == ("pod", "data", "head", "outer", "inner")
    assert mesh.devices.shape == (2, 16, 8, 1, 2)
    # head-first: the head axis is minor => consecutive device ids along it
    row = mesh.devices[0, 0, :, 0, 0]
    assert [d.id for d in row] == list(range(8))
    # ...and the inner ring strides across (ICI-remote)
    inner_row = mesh.devices[0, 0, 0, 0, :]
    assert [d.id for d in inner_row] == [0, 8]
    pc_cf = ParallelConfig(dp=16, hp=8, cp_outer=1, cp_inner=2, pods=2,
                           placement="context_first")
    mesh_cf = refine_mesh(base, pc_cf)
    # context-first: inner ring minor (consecutive), head strided
    assert [d.id for d in mesh_cf.devices[0, 0, 0, 0, :]] == [0, 1]
    assert [d.id for d in mesh_cf.devices[0, 0, :, 0, 0]] == \
        [0, 2, 4, 6, 8, 10, 12, 14]


def test_cell_shapes_shardable():
    """Every (arch × shape) cell divides cleanly on the production mesh."""
    from repro.configs import all_arch_names, get_config, get_parallel
    from repro.configs.common import SHAPES, applicable_shapes
    for arch in all_arch_names():
        cfg = get_config(arch)
        for shape_name in applicable_shapes(arch):
            shape = SHAPES[shape_name]
            pc = get_parallel(arch, shape_name, False)
            assert pc.sp == 16
            assert shape.seq_len % pc.sp == 0, (arch, shape_name)
            if shape.kind == "train" and cfg.zigzag:
                assert (shape.seq_len // pc.cp) % 2 == 0
            if cfg.family in ("dense", "moe") and cfg.mla is None:
                assert cfg.n_heads % pc.hp == 0, (arch, pc.hp)
                if pc.hp > cfg.n_kv_heads:
                    assert pc.hp % cfg.n_kv_heads == 0
