"""Test fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device; distributed tests spawn subprocesses with their own flags."""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def single_runtime():
    import jax
    from repro.core.runtime import Runtime
    from repro.core.topology import ParallelConfig, make_mesh
    pc = ParallelConfig()
    mesh = make_mesh(pc, devices=jax.devices()[:1])
    return Runtime(mesh=mesh, pc=pc, impl="ref")
