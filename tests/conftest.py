"""Test fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device; distributed tests spawn subprocesses with their own flags."""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:                                    # pragma: no cover
    import hypothesis                   # noqa: F401
except ImportError:
    # Property tests degrade to a deterministic fixed-seed sweep (see
    # _hypothesis_stub.py) instead of failing collection.  ``pip install
    # -r requirements-dev.txt`` restores the real shrinking search.
    import importlib.util
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        os.path.join(os.path.dirname(__file__), "_hypothesis_stub.py"))
    _stub = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_stub)
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _stub.strategies


@pytest.fixture(scope="session")
def single_runtime():
    import jax
    from repro.core.runtime import Runtime
    from repro.core.topology import ParallelConfig, make_mesh
    pc = ParallelConfig()
    mesh = make_mesh(pc, devices=jax.devices()[:1])
    return Runtime(mesh=mesh, pc=pc, impl="ref")
