"""CheckpointManager unit tests: manifest format-2 round-trip, crash-sim
atomicity, restore-time resharding through a plan, v1-format compat,
async-writer serialization, StepMonitor flagging, PreemptionGuard scoping,
and the step-indexed resume contract.  (Cross-plan/cross-extent elastic
restore runs on a real 8-device mesh in tests/_dist_checks.py.)"""
import json
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.plan import build_plan
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.model import init_params
from repro.runtime import checkpoint as ckpt
from repro.runtime.resilience import PreemptionGuard, StepMonitor
from repro.train.optimizer import init_opt_state


def _plan(cfg):
    return build_plan(cfg, devices=jax.devices()[:1], impl="ref",
                      seq_len=64, global_batch=4)


def _state(cfg):
    params = init_params(cfg, jax.random.PRNGKey(0))
    return {"params": params, "opt": init_opt_state(params)}


# ---------------------------------------------------------------------------
# manifest format 2
# ---------------------------------------------------------------------------

def test_manifest_records_plan_and_bytes(tmp_path):
    cfg = get_reduced("qwen3-1.7b")
    plan, state = _plan(cfg), _state(cfg)
    mgr = ckpt.CheckpointManager(str(tmp_path), plan=plan)
    mgr.save(state, 3)
    man = mgr.manifest()
    assert man["format"] == ckpt.FORMAT == 2
    assert man["step"] == 3
    assert man["plan"]["dp"] == 1
    assert man["plan"]["zero_mode"] == plan.zero_mode
    assert man["plan"]["zero_extent"] == plan.mem["zero_extent"]
    # on one device every leaf saves whole: bytes/host == full state
    leaves = jax.tree.leaves(state)
    assert man["bytes_per_host"] == sum(np.asarray(x).nbytes
                                        for x in leaves)
    assert len(man["leaves"]) == len(leaves)
    for e in man["leaves"]:
        assert e["shards"] == 1 and e["dim"] is None

    got, step = mgr.restore(jax.tree.map(jnp.zeros_like, state))
    assert step == 3
    for a, b in zip(jax.tree.leaves(got), leaves):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sharded_leaf_files_roundtrip(tmp_path):
    """Per-shard layout on disk: a leaf split 4 ways writes 4 files, the
    manifest records (dim, shards), bytes/host counts one shard, and
    restore reassembles the leaf exactly."""
    x = np.arange(32, dtype=np.float32).reshape(8, 4)
    tree = {"x": x}
    paths, leaves, _ = ckpt._flatten_with_paths(tree)
    final = ckpt._write_checkpoint(str(tmp_path), 1, paths, leaves,
                                   [(0, 4)], {"dp": 4})
    shard_files = sorted(f for f in os.listdir(final) if f.endswith(".npy"))
    assert shard_files == [f"leaf_0.s{j}.npy" for j in range(4)]
    man = ckpt.read_manifest(str(tmp_path))
    assert man["leaves"][0] == {"path": paths[0], "shape": [8, 4],
                                "dtype": "float32", "dim": 0, "shards": 4}
    assert man["bytes_per_host"] == x.nbytes // 4
    got, step = ckpt.restore({"x": np.zeros_like(x)}, str(tmp_path))
    assert step == 1
    np.testing.assert_array_equal(got["x"], x)


def test_v1_whole_leaf_checkpoints_still_restore(tmp_path):
    """The seed layout (one leaf_<i>.npy per leaf, no format field) reads
    back through the same restore path."""
    tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": np.ones(3, np.float32)}
    paths, leaves, _ = ckpt._flatten_with_paths(tree)
    d = tmp_path / "step_00000007"
    d.mkdir()
    manifest = {"step": 7, "leaves": []}        # no "format": seed era
    for i, (p, x) in enumerate(zip(paths, leaves)):
        np.save(str(d / f"leaf_{i}.npy"), x)
        manifest["leaves"].append({"path": p, "shape": list(x.shape),
                                   "dtype": str(x.dtype)})
    with open(d / "manifest.json", "w") as f:
        json.dump(manifest, f)
    got, step = ckpt.restore(jax.tree.map(np.zeros_like, tree),
                             str(tmp_path))
    assert step == 7
    np.testing.assert_array_equal(got["w"], tree["w"])
    np.testing.assert_array_equal(got["b"], tree["b"])


# ---------------------------------------------------------------------------
# atomicity
# ---------------------------------------------------------------------------

def test_crash_mid_write_leaves_no_trace(tmp_path, monkeypatch):
    """A crash between shard files must leave neither a visible
    checkpoint nor a stale tmp dir (the writer cleans up and re-raises)."""
    state = {"a": np.zeros(4, np.float32), "b": np.ones(4, np.float32)}
    real_save, calls = np.save, []

    def boom(path, arr, **kw):
        calls.append(path)
        if len(calls) > 1:
            raise OSError("disk gone")
        real_save(path, arr, **kw)

    monkeypatch.setattr(np, "save", boom)
    with pytest.raises(OSError):
        ckpt.save(state, 5, str(tmp_path))
    assert len(calls) == 2                     # it really died mid-write
    assert ckpt.list_steps(str(tmp_path)) == []
    assert os.listdir(str(tmp_path)) == []     # tmp dir removed


# ---------------------------------------------------------------------------
# restore-time resharding
# ---------------------------------------------------------------------------

def test_restore_reshards_through_target_plan(tmp_path):
    """``manager.restore`` device_puts through the plan's
    ``state_shardings`` — every restored leaf is a committed device array
    matching the plan's layout, not host numpy."""
    cfg = get_reduced("qwen3-1.7b")
    plan, state = _plan(cfg), _state(cfg)
    mgr = ckpt.CheckpointManager(str(tmp_path), plan=plan)
    mgr.save(state, 1)
    got, _ = mgr.restore(jax.tree.map(jnp.zeros_like, state))
    sh = plan.state_shardings(state)
    for a, s in zip(jax.tree.leaves(got), jax.tree.leaves(
            sh, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))):
        assert isinstance(a, jax.Array)
        assert a.sharding.is_equivalent_to(s, a.ndim)
    # an explicit shardings pytree overrides the plan
    got2, _ = mgr.restore(jax.tree.map(jnp.zeros_like, state),
                          shardings=sh)
    for a, b in zip(jax.tree.leaves(got2), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# async writer serialization
# ---------------------------------------------------------------------------

def test_save_async_rapid_fire_serializes(tmp_path):
    """Back-to-back ``save_async`` calls never race on the directory:
    every step lands, no tmp dirs leak, and ``flush`` is idempotent."""
    mgr = ckpt.CheckpointManager(str(tmp_path), keep=100)
    state = {"x": np.arange(4096, dtype=np.float32)}
    for s in range(1, 9):
        mgr.save_async(state, s)
    mgr.flush()
    assert mgr.list_steps() == list(range(1, 9))
    assert not [n for n in os.listdir(str(tmp_path)) if ".tmp" in n]
    mgr.flush()                                # no-op when idle
    assert mgr.latest_step() == 8


def test_save_async_gc_applies_keep(tmp_path):
    mgr = ckpt.CheckpointManager(str(tmp_path), keep=2)
    state = {"x": np.zeros(8, np.float32)}
    for s in (1, 2, 3, 4):
        mgr.save_async(state, s)
    mgr.wait()                                 # AsyncCheckpointer alias
    assert mgr.list_steps() == [3, 4]


# ---------------------------------------------------------------------------
# resilience plumbing
# ---------------------------------------------------------------------------

def test_step_monitor_flags_and_reports_outliers():
    mon = StepMonitor(window=50, threshold=1.5)
    for i in range(1, 11):
        mon.record(i, 0.1)
    mon.record(11, 0.5)
    assert mon.flagged
    step, dt, med = mon.flagged[-1]
    assert step == 11 and dt == 0.5 and abs(med - 0.1) < 1e-9
    assert mon.report()["stragglers"] == mon.flagged


def test_preemption_guard_install_is_scoped():
    """``install`` displaces the previous handler, ``uninstall`` puts it
    back — a guard never clobbers the process signal setup for good."""
    seen = []
    prev = signal.signal(signal.SIGTERM, lambda s, f: seen.append(s))
    try:
        g = PreemptionGuard()
        g.install()
        g.install()                            # idempotent
        signal.raise_signal(signal.SIGTERM)
        assert g.requested
        assert seen == []                      # ours, not the old handler
        g.uninstall()
        signal.raise_signal(signal.SIGTERM)
        assert seen == [signal.SIGTERM]        # old handler restored
    finally:
        signal.signal(signal.SIGTERM, prev)


def test_iter_batches_resume_is_a_skip_not_a_replay():
    """``batch(step)`` keys on (seed, step) only, so iterating from a
    restore point yields exactly the uninterrupted run's batches."""
    cfg = DataConfig(vocab=101, seq_len=32, global_batch=4, cp=1,
                     zigzag=False)
    data = SyntheticLM(cfg)
    full = [b for _, b in data.iter_batches(0, 6)]
    resumed = list(data.iter_batches(4, 2))
    assert [s for s, _ in resumed] == [4, 5]
    for (_, b), ref in zip(resumed, full[4:]):
        for k in b:
            np.testing.assert_array_equal(b[k], ref[k])
