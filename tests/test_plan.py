"""ExecutionPlan: placement orderings, AMSP ZeRO selection, sub-group
fallback, describe(), and microbatched gradient accumulation."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.core.plan import build_plan, choose_zero_mode
from repro.core.topology import (AXIS_DATA, AXIS_HP, AXIS_INNER, AXIS_OUTER,
                                 ParallelConfig)
from repro.core.zero import leaf_extent, leaf_spec


class FakeDev:
    def __init__(self, i):
        self.id = i

    def __repr__(self):
        return f"d{self.id}"


def _fake_devs(n):
    return [FakeDev(i) for i in range(n)]


CFG = get_reduced("qwen3-1.7b")


# ---------------------------------------------------------------------------
# placement
# ---------------------------------------------------------------------------

def test_placement_minor_axis_orderings():
    """head_first: SeqAlltoAll (head) group gets consecutive device ids;
    context_first: the inner ring does — on a fake 16-device mesh."""
    pc_hf = ParallelConfig(hp=4, cp_outer=2, cp_inner=2,
                           placement="head_first")
    plan = build_plan(CFG, pc_hf, devices=_fake_devs(16))
    dev = plan.mesh.devices
    assert dev.shape == (1, 1, 4, 2, 2)
    assert [d.id for d in dev[0, 0, :, 0, 0]] == [0, 1, 2, 3]   # head minor
    assert [d.id for d in dev[0, 0, 0, 0, :]] == [0, 4]         # inner strided
    assert [d.id for d in dev[0, 0, 0, :, 0]] == [0, 8]         # outer strided

    pc_cf = ParallelConfig(hp=4, cp_outer=2, cp_inner=2,
                           placement="context_first")
    plan = build_plan(CFG, pc_cf, devices=_fake_devs(16))
    dev = plan.mesh.devices
    assert [d.id for d in dev[0, 0, 0, 0, :]] == [0, 1]         # inner minor
    assert [d.id for d in dev[0, 0, 0, :, 0]] == [0, 2]
    assert [d.id for d in dev[0, 0, :, 0, 0]] == [0, 4, 8, 12]  # head strided


def test_describe_reports_the_whole_plan():
    plan = build_plan(CFG, opt=None, devices=jax.devices()[:1],
                      grad_accum=2, seq_len=128, global_batch=8)
    s = plan.describe()
    for frag in ("placement=head_first", "grad_accum=2", "microbatch=4",
                 "remat", "zero", "leaf extents", "memory/dev"):
        assert frag in s, (frag, s)


# ---------------------------------------------------------------------------
# hybrid-ZeRO selection (AMSP) + sub-group fallback
# ---------------------------------------------------------------------------

def _fake_mesh(pc, n):
    from repro.core.topology import make_mesh
    return make_mesh(pc, devices=_fake_devs(n))


def test_zero_mode_from_memory_model():
    """The least-sharded AMSP mode whose param+opt state fits the budget
    wins (replica < dp < sp < dp×sp)."""
    mesh = _fake_mesh(ParallelConfig(dp=16, hp=8, cp_outer=1, cp_inner=2),
                      256)
    budget = 16e9
    # tiny model: replicate everywhere
    assert choose_zero_mode(int(1e6), mesh, budget)[0] == "replica"
    # 2B params: 28 GB of state; dp-wide (/16) fits
    assert choose_zero_mode(int(2e9), mesh, budget)[0] == "dp"
    # 100B params: only the full dp×sp extent (/256) fits
    assert choose_zero_mode(int(100e9), mesh, budget)[0] == "dp_sp"


def test_leaf_spec_subgroup_fallback():
    """A leaf whose dims don't divide the full group falls back to the
    largest divisible sub-group (dropping minor axes) — not to replica."""
    mesh = _fake_mesh(ParallelConfig(dp=4, hp=2), 8)
    group = (AXIS_DATA, AXIS_HP, AXIS_OUTER, AXIS_INNER)
    # divisible by the full 8-way group: shard 8-wide
    assert leaf_extent((16, 8), mesh, (group,), min_elems=1) == (8, group)
    # 12 % 8 != 0 but 12 % 4 == 0: falls back to (data,) 4-wide
    ext, axes = leaf_extent((12, 4), mesh, (group,), min_elems=1)
    assert (ext, axes) == (4, (AXIS_DATA,))
    spec = leaf_spec((12, 4), mesh, (group,), min_elems=1)
    assert spec == jax.sharding.PartitionSpec((AXIS_DATA,), None)
    # nothing divides: replicate
    assert leaf_extent((7, 5), mesh, (group,), min_elems=1) == (1, ())


def test_plan_leaf_extents_surface_fallbacks():
    """describe()/leaf_extents reports the extent per top-level leaf
    class under the chosen groups."""
    pc = ParallelConfig(dp=4, hp=2)
    plan = build_plan(CFG, pc, devices=_fake_devs(8), zero="dp_sp")
    ext = plan.leaf_extents()
    assert "embed" in ext and "blocks" in ext
    # the vocab=512 embedding divides the full 8-way group
    assert max(e for e, _ in ext["embed"]) == 8


# ---------------------------------------------------------------------------
# gradient accumulation
# ---------------------------------------------------------------------------

def _step_inputs(plan, seq=64, gb=8):
    from repro.data.pipeline import SyntheticLM
    from repro.models.model import init_params
    from repro.train.optimizer import init_opt_state
    data = SyntheticLM(plan.data_config(seq, gb), plan.cfg)
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    params = init_params(plan.cfg, jax.random.PRNGKey(0))
    return params, init_opt_state(params), batch


def test_grad_accum_matches_large_batch():
    """grad_accum=4 on (4, 2, S) microbatches == one batch-8 step, in
    fp32, for params, opt state and metrics."""
    from repro.train.train_step import jit_train_step
    plan4 = build_plan(CFG, devices=jax.devices()[:1], grad_accum=4,
                       seq_len=64, global_batch=8)
    plan1 = build_plan(CFG, devices=jax.devices()[:1], grad_accum=1,
                       seq_len=64, global_batch=8)
    params, opt, batch4 = _step_inputs(plan4)
    assert batch4["tokens"].shape == (4, 2, 64)
    batch1 = {k: v.reshape((8,) + v.shape[2:]) for k, v in batch4.items()}

    with plan4.mesh:
        step4, _, _ = jit_train_step(plan4, params, donate=False)
        p4, o4, m4 = step4(params, opt, batch4)
    with plan1.mesh:
        step1, _, _ = jit_train_step(plan1, params, donate=False)
        p1, o1, m1 = step1(params, opt, batch1)

    np.testing.assert_allclose(float(m4["loss"]), float(m1["loss"]),
                               rtol=1e-6)
    assert float(m4["n_tokens"]) == float(m1["n_tokens"])
    for a, b in zip(jax.tree.leaves(p4), jax.tree.leaves(p1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(o4["m"]), jax.tree.leaves(o1["m"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-7)


def _walk_jaxprs(jaxpr):
    """Yield jaxpr and every nested sub-jaxpr (scan/remat/cond bodies)."""
    yield jaxpr
    for eqn in jaxpr.eqns:
        for v in eqn.params.values():
            vs = v if isinstance(v, (tuple, list)) else (v,)
            for u in vs:
                inner = getattr(u, "jaxpr", None)
                if inner is not None and hasattr(inner, "eqns"):
                    yield from _walk_jaxprs(inner)
                elif hasattr(u, "eqns"):
                    yield from _walk_jaxprs(u)


def _count_prim(jaxpr, name):
    return sum(1 for j in _walk_jaxprs(jaxpr) for e in j.eqns
               if e.primitive.name == name)


def test_grad_accum_single_reduction_point():
    """Structural jaxpr check: grads leave the microbatch scan as one
    carry, and the optimizer update (sqrt ops — the point where grads
    are reduced into the ZeRO-sharded AdamW state) runs once per step,
    outside the loop, not once per microbatch."""
    from repro.train.train_step import make_train_step

    def trace(accum):
        plan = build_plan(CFG, devices=jax.devices()[:1], grad_accum=accum,
                          seq_len=64, global_batch=8)
        params, opt, batch = _step_inputs(plan)
        return jax.make_jaxpr(make_train_step(plan))(params, opt, batch)

    j1, j4 = trace(1), trace(4)
    # the whole-program optimizer footprint must not scale with accum
    assert _count_prim(j4.jaxpr, "sqrt") == _count_prim(j1.jaxpr, "sqrt")

    outer_scans = [e for e in j4.jaxpr.eqns if e.primitive.name == "scan"
                   and e.params.get("length") == 4]
    assert len(outer_scans) == 1, \
        [e.primitive.name for e in j4.jaxpr.eqns]
    body = outer_scans[0].params["jaxpr"].jaxpr
    # no optimizer math inside the microbatch loop
    assert _count_prim(body, "sqrt") == 0
    # the scan carries exactly the grad tree: one leaf per param leaf
    from repro.models.model import init_params
    n_params = len(jax.tree.leaves(jax.eval_shape(
        lambda: init_params(CFG, jax.random.PRNGKey(0)))))
    assert outer_scans[0].params["num_carry"] == n_params


def test_batch_shardings_follow_accum_layout():
    plan = build_plan(CFG, devices=jax.devices()[:1], grad_accum=2,
                      seq_len=64, global_batch=8)
    sh = plan.batch_shardings("train")
    spec = sh["tokens"].spec
    assert spec[0] is None          # replicated accumulation axis
    flat = build_plan(CFG, devices=jax.devices()[:1], grad_accum=1,
                      seq_len=64, global_batch=8)
    assert len(flat.batch_shardings("train")["tokens"].spec) == 2


# ---------------------------------------------------------------------------
# FPDT chunk-offload memory model (device-free via _ShapeOnlyMesh)
# ---------------------------------------------------------------------------

def test_offload_split_conserves_bytes():
    from repro.core.plan import offload_resident_frac, offload_split
    assert offload_resident_frac(1) == 1.0
    assert offload_resident_frac(2) == 1.0       # both chunks resident
    assert offload_resident_frac(8) == 0.25      # active + prefetched of 8
    for chunks in (1, 2, 4, 8, 16):
        dev, host = offload_split(1e9, chunks)
        assert dev + host == 1e9                 # nothing double-counted
        assert dev == 1e9 * offload_resident_frac(chunks)
        assert host >= 0


def test_plan_memory_offload_trades_hbm_for_wire():
    from repro.core.plan import plan_memory
    pc = ParallelConfig(dp=1, hp=2, cp_outer=2, cp_inner=2)
    mems = {}
    for chunks in (1, 4, 8, 16):
        _, _, _, mem = plan_memory(CFG, pc, remat="none",
                                   memory_budget_gb=0.05, seq_len=8192,
                                   global_batch=8, offload_chunks=chunks)
        mems[chunks] = mem
    total = mems[1]["act_dev"]
    for a, b in ((1, 4), (4, 8), (8, 16)):
        assert mems[b]["act_dev"] < mems[a]["act_dev"]        # HBM freed …
        assert mems[b]["act_host"] > mems[a]["act_host"]      # … to host
        assert mems[b]["offload_wire_s"] > mems[a]["offload_wire_s"]
    for mem in mems.values():
        assert mem["act_dev"] + mem["act_host"] == total      # conserved
    assert mems[1]["offload_wire_s"] == 0.0
    # max trainable seq scales as 1/resident_frac = C/2 at a fixed budget
    base = mems[1]["max_seq_at_budget"]
    assert base > 0
    assert mems[8]["max_seq_at_budget"] >= 4 * base
    assert mems[16]["max_seq_at_budget"] >= 8 * base


def test_max_seq_at_budget_monotone_in_budget():
    from repro.core.plan import plan_memory
    pc = ParallelConfig(dp=1, hp=2, cp_outer=2, cp_inner=2)
    prev = -1
    for budget in (0.02, 0.05, 0.1, 0.5, 1.0):
        _, _, _, mem = plan_memory(CFG, pc, remat="none",
                                   memory_budget_gb=budget, seq_len=8192,
                                   global_batch=8, offload_chunks=8)
        assert mem["max_seq_at_budget"] >= prev, budget
        prev = mem["max_seq_at_budget"]
    assert prev > 0


def test_describe_reports_offload_line():
    plan = build_plan(CFG, devices=jax.devices()[:1], seq_len=128,
                      global_batch=8, offload_chunks=4)
    assert plan.offload_chunks == 4
    s = plan.describe()
    for frag in ("offload", "chunks=4", "max_seq@budget"):
        assert frag in s, (frag, s)
    # resident plans still print the line (chunks=1, no wire term)
    plan1 = build_plan(CFG, devices=jax.devices()[:1], seq_len=128,
                       global_batch=8)
    assert plan1.offload_chunks == 1
    assert "chunks=1" in plan1.describe()


def test_serve_spec_reuses_offload_accounting():
    """The serve memory model charges only the resident fraction of a KV
    block under offload — the same ``offload_split`` as training — so KV
    bytes are never double-counted device-side and the freed HBM shows up
    as a larger paged pool."""
    plan = build_plan(CFG, devices=jax.devices()[:1], seq_len=128,
                      global_batch=8, memory_budget_gb=0.05)
    kw = dict(max_seq_len=4096, max_batch=64)
    sv1 = plan.serve_spec(offload_chunks=1, **kw)
    sv8 = plan.serve_spec(offload_chunks=8, **kw)
    assert sv8.num_blocks > sv1.num_blocks    # freed HBM -> more pages fit
    # the logical per-token bytes are unchanged: only residency moved
    assert sv8.paged_bytes_per_token == sv1.paged_bytes_per_token
    assert sv8.max_blocks_per_seq == sv1.max_blocks_per_seq
