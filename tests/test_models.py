"""Per-architecture smoke tests (reduced configs, 1 CPU device):
forward/train step with shape + finiteness asserts, prefill/decode paths,
decode == incremental-forward consistency, and a short learning check."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import all_arch_names, get_config, get_reduced
from repro.configs.common import SHAPES, applicable_shapes
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.decode import (decode_step, grow_caches,
                                 init_caches, prefill)
from repro.models.model import forward_loss, init_params

ARCHS = all_arch_names()


def _batch(cfg, b, s, key=0):
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=s,
                                  global_batch=b, cp=1, zigzag=False,
                                  seed=key), cfg)
    return {k: jnp.asarray(v) for k, v in data.batch(0).items()}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch, single_runtime):
    rt = single_runtime
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, 2, 32)
    with rt.mesh:
        (loss, metrics), grads = jax.jit(jax.value_and_grad(
            lambda p: forward_loss(p, batch, rt, cfg),
            has_aux=True))(params)
    assert np.isfinite(float(loss)), arch
    assert float(metrics["n_tokens"]) == 64
    gnorm = np.sqrt(sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                        for g in jax.tree.leaves(grads)))
    assert np.isfinite(gnorm) and gnorm > 0, arch
    # every param leaf matches its grad leaf's shape
    for p, g in zip(jax.tree.leaves(params), jax.tree.leaves(grads)):
        assert p.shape == g.shape


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode(arch, single_runtime):
    rt = single_runtime
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    pf = {"tokens": batch["tokens"]}
    if cfg.family == "encdec":
        pf["frames"] = batch["frames"]
    with rt.mesh:
        logits, caches = prefill(params, pf, rt, cfg)
        assert logits.shape == (B, 1, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all(), arch
        nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        lg2, caches2 = decode_step(params, caches, nxt, jnp.int32(S), rt,
                                   cfg)
        assert lg2.shape == (B, 1, cfg.vocab)
        assert np.isfinite(np.asarray(lg2)).all(), arch
        # cache pytree structure is stable across steps
        assert jax.tree.structure(caches) == jax.tree.structure(caches2)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "gemma2-2b",
                                  "deepseek-v2-lite-16b",
                                  "falcon-mamba-7b", "zamba2-7b",
                                  "whisper-small"])
def test_decode_matches_incremental_forward(arch, single_runtime):
    """Greedy continuation via decode_step == re-running prefill on the
    extended prompt (KV caches, ring buffers and SSM states are exact)."""
    rt = single_runtime
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S, T = 1, 16, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S + T), 0,
                                cfg.vocab)
    pf = {"tokens": tokens[:, :S]}
    full = {"tokens": tokens}
    if cfg.family == "encdec":
        frames = jax.random.normal(jax.random.PRNGKey(2),
                                   (B, cfg.enc_frames, cfg.d_model))
        pf["frames"] = frames
        full["frames"] = frames
    with rt.mesh:
        # decode path: prefill on S tokens then feed the known next tokens
        _, caches = prefill(params, pf, rt, cfg)
        caches = grow_caches(cfg, caches, T)
        dec_logits = []
        for t in range(T):
            lg, caches = decode_step(params, caches, tokens[:, S + t:S + t + 1],
                                     jnp.int32(S + t), rt, cfg)
            dec_logits.append(np.asarray(lg[:, 0]))
        # oracle: prefill over the full prompt gives the last-token logits
        lg_full, _ = prefill(params, full, rt, cfg)
    np.testing.assert_allclose(dec_logits[-1], np.asarray(lg_full[:, 0]),
                               atol=2e-3, rtol=2e-3)


def test_full_configs_match_assignment():
    """The full-size configs carry the exact assigned dims."""
    spec = {
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "falcon-mamba-7b": (64, 4096, 1, 1, 0, 65024),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert cfg.num_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.n_heads == h, arch
        assert cfg.n_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab == v, arch
    assert get_config("qwen3-moe-30b-a3b").moe.n_experts == 128
    assert get_config("qwen3-moe-30b-a3b").moe.top_k == 8
    assert get_config("deepseek-v2-lite-16b").moe.n_experts == 64
    assert get_config("deepseek-v2-lite-16b").moe.top_k == 6
    assert get_config("deepseek-v2-lite-16b").mla.kv_lora == 512
    assert get_config("zamba2-7b").ssm2.d_state == 64
    assert get_config("falcon-mamba-7b").ssm1.d_state == 16


def test_shape_applicability():
    assert "long_500k" not in applicable_shapes("qwen3-1.7b")
    assert "long_500k" in applicable_shapes("falcon-mamba-7b")
    assert "long_500k" in applicable_shapes("zamba2-7b")
    assert "long_500k" in applicable_shapes("gemma3-12b")
    assert "decode_32k" in applicable_shapes("whisper-small")
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["long_500k"].seq_len == 524288


def test_selective_checkpoint_changes_residuals(single_runtime):
    """SC++ ('scpp') vs full remat produce identical losses/grads but
    different saved-residual sets (sanity that the policy is wired)."""
    import dataclasses
    rt = single_runtime
    base = get_reduced("qwen3-1.7b")
    batch = _batch(base, 2, 32)
    results = {}
    for remat in ("none", "full", "scpp"):
        cfg = dataclasses.replace(base, remat=remat)
        params = init_params(cfg, jax.random.PRNGKey(0))
        with rt.mesh:
            loss, grads = jax.value_and_grad(
                lambda p: forward_loss(p, batch, rt, cfg)[0])(params)
        results[remat] = (float(loss), grads)
    for a in ("full", "scpp"):
        assert abs(results[a][0] - results["none"][0]) < 1e-5
        for g1, g2 in zip(jax.tree.leaves(results[a][1]),
                          jax.tree.leaves(results["none"][1])):
            np.testing.assert_allclose(g1, g2, atol=1e-4, rtol=1e-4)
