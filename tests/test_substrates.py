"""Substrate tests: zigzag layout, ZeRO sharding rules, checkpoint/restore
(incl. elastic reshard), optimizer, compression, resilience utilities."""
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.zigzag import (from_zigzag, to_zigzag, zigzag_indices,
                               zigzag_inverse)
from repro.core.topology import ParallelConfig, make_mesh
from repro.core.zero import leaf_spec, zero_shardings
from repro.runtime import checkpoint as ckpt
from repro.runtime.resilience import StepMonitor, elastic_plan
from repro.train.optimizer import (OptConfig, adamw_update, dequantize_int8,
                                   global_norm, init_opt_state,
                                   quantize_int8, schedule)


# ---------------------------------------------------------------------------
# zigzag
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(cp=st.sampled_from([1, 2, 4, 8, 16]), mult=st.integers(1, 4))
def test_zigzag_inverse_property(cp, mult):
    s = 2 * cp * mult
    idx = zigzag_indices(s, cp)
    inv = zigzag_inverse(s, cp)
    assert (idx[inv] == np.arange(s)).all()
    assert sorted(idx.tolist()) == list(range(s))


def test_zigzag_balanced_ownership():
    """rank r owns logical chunks (r, 2cp-1-r)."""
    s, cp = 32, 4
    c = s // (2 * cp)
    idx = zigzag_indices(s, cp)
    for r in range(cp):
        block = idx[r * 2 * c:(r + 1) * 2 * c]
        chunks = sorted(set(b // c for b in block))
        assert chunks == [r, 2 * cp - 1 - r]


def test_zigzag_roundtrip_array():
    x = jnp.arange(2 * 48).reshape(2, 48)
    y = from_zigzag(to_zigzag(x, 4), 4)
    np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# ZeRO sharding rules
# ---------------------------------------------------------------------------

def test_zero_leaf_rules():
    # leaf_spec only reads mesh.shape — an abstract 8-way mesh suffices
    names = ("pod", "data", "head", "outer", "inner")
    sizes = (1, 2, 2, 1, 2)
    try:
        mesh = jax.sharding.AbstractMesh(sizes, names)
    except TypeError:   # older spelling: tuple of (name, size) pairs
        mesh = jax.sharding.AbstractMesh(tuple(zip(names, sizes)))
    # big leaf divisible by full group (8) -> sharded on largest dim
    spec = leaf_spec((128, 512), mesh)
    assert spec[1] is not None
    # tiny leaf -> replicated
    assert leaf_spec((8,), mesh) == jax.sharding.PartitionSpec()
    # divisible only by dp (2-way) -> falls back to a smaller group
    spec = leaf_spec((100002, 7), mesh)
    assert spec != jax.sharding.PartitionSpec()


def test_zero_shardings_cover_params(single_runtime):
    from repro.configs import get_reduced
    from repro.models.model import init_params
    cfg = get_reduced("qwen3-1.7b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    sh = zero_shardings(params, single_runtime.mesh)
    assert jax.tree.structure(sh) == jax.tree.structure(params)


# ---------------------------------------------------------------------------
# checkpoint / elastic restore
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(tree, 7, d)
        assert ckpt.list_steps(d) == [7]
        restored, step = ckpt.restore(tree, d)
        assert step == 7
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(a, b)


def test_checkpoint_atomicity_tmp_invisible():
    tree = {"a": jnp.zeros((4,))}
    with tempfile.TemporaryDirectory() as d:
        os.makedirs(os.path.join(d, "step_00000009.tmp"))
        assert ckpt.list_steps(d) == []          # half-written is invisible
        ckpt.save(tree, 9, d)
        assert ckpt.list_steps(d) == [9]


def test_async_checkpointer_gc():
    tree = {"a": jnp.zeros((4,))}
    with tempfile.TemporaryDirectory() as d:
        c = ckpt.AsyncCheckpointer(d, keep=2)
        for s in (1, 2, 3):
            c.save_async(tree, s)
        c.wait()
        assert ckpt.list_steps(d) == [2, 3]


def test_elastic_restore_resharding():
    """Save under one sharding, restore under another — the elastic path."""
    pc = ParallelConfig(dp=1)
    mesh = make_mesh(pc, devices=jax.devices()[:1])
    x = {"w": jnp.arange(64.0).reshape(8, 8)}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(x, 0, d)
        sh = zero_shardings(x, mesh)
        restored, _ = ckpt.restore(x, d, shardings=sh)
        np.testing.assert_array_equal(restored["w"], x["w"])


# ---------------------------------------------------------------------------
# optimizer + compression
# ---------------------------------------------------------------------------

def test_adamw_decreases_quadratic():
    p = {"w": jnp.array([3.0, -2.0])}
    s = init_opt_state(p)
    cfg = OptConfig(lr=0.1, warmup_steps=0, total_steps=1000,
                    weight_decay=0.0, clip_norm=1e9)
    for _ in range(200):
        g = {"w": 2 * p["w"]}
        p, s, _ = adamw_update(p, g, s, cfg)
    assert float(jnp.abs(p["w"]).max()) < 0.2


def test_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                    min_lr_ratio=0.1)
    lrs = [float(schedule(cfg, jnp.int32(s))) for s in range(101)]
    assert lrs[0] < lrs[9] <= 1.0
    assert abs(lrs[10] - 1.0) < 1e-6
    assert lrs[100] == pytest.approx(0.1, abs=1e-5)


def test_grad_clip():
    p = {"w": jnp.zeros((2,))}
    s = init_opt_state(p)
    cfg = OptConfig(lr=1.0, warmup_steps=0, clip_norm=1.0,
                    weight_decay=0.0)
    _, _, m = adamw_update(p, {"w": jnp.array([30.0, 40.0])}, s, cfg)
    assert float(m["grad_norm"]) == pytest.approx(50.0, rel=1e-5)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 16), steps=st.integers(5, 30))
def test_int8_error_feedback_unbiased(seed, steps):
    """Error feedback: the *cumulative* quantized sum tracks the exact sum
    to within one quantization step (not O(steps) drift)."""
    rng = np.random.default_rng(seed)
    err = jnp.zeros((32,))
    acc_q = np.zeros((32,))
    acc_x = np.zeros((32,))
    max_scale = 0.0
    for s in range(steps):
        x = jnp.asarray(rng.standard_normal(32), jnp.float32)
        q, scale, err = quantize_int8(x, err)
        acc_q += np.asarray(dequantize_int8(q, scale))
        acc_x += np.asarray(x)
        max_scale = max(max_scale, float(scale))
    assert np.abs(acc_q - acc_x).max() <= max_scale * 1.01 + 1e-6


# ---------------------------------------------------------------------------
# resilience
# ---------------------------------------------------------------------------

def test_step_monitor_flags_stragglers():
    m = StepMonitor(window=20, threshold=1.5)
    for i in range(20):
        m.record(i, 1.0)
    m.record(20, 5.0)
    assert len(m.flagged) == 1
    assert m.report()["stragglers"][0][0] == 20


def test_elastic_plan_valid():
    for chips in (256, 128, 64, 48, 17, 8, 1):
        pc = elastic_plan(chips, kv_heads=8, n_heads=16)
        assert pc.num_devices <= chips
        assert 16 % pc.hp == 0 or pc.hp == 1


def test_data_determinism_and_layout():
    from repro.data.pipeline import DataConfig, SyntheticLM
    d1 = SyntheticLM(DataConfig(vocab=100, seq_len=16, global_batch=2,
                                cp=4, zigzag=True, seed=3))
    d2 = SyntheticLM(DataConfig(vocab=100, seq_len=16, global_batch=2,
                                cp=4, zigzag=True, seed=3))
    b1, b2 = d1.batch(5), d2.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # positions are the zigzag permutation itself
    np.testing.assert_array_equal(b1["positions"][0],
                                  zigzag_indices(16, 4))


def test_global_norm():
    assert float(global_norm({"a": jnp.array([3.0]),
                              "b": jnp.array([4.0])})) == pytest.approx(5.0)
