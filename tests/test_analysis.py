"""Analysis-layer units: HLO collective parser, wire model, roofline terms,
report rendering — these numbers are the §Roofline deliverable, so they get
their own oracle tests."""
import numpy as np
import pytest

from repro.analysis.hlo import (_parse_def, _participants, _shape_bytes,
                                _wire_multiplier, parse_collective_bytes)
from repro.analysis.roofline import (RooflineTerms, terms_from_record,
                                     model_flops)

HLO = """
HloModule jit_step

ENTRY %main (p0: f32[16,128]) -> f32[16,128] {
  %p0 = f32[16,128]{1,0} parameter(0)
  %ag = f32[256,128]{1,0} all-gather(%p0), channel_id=1, replica_groups=[16,16]<=[256], dimensions={0}
  %ar = f32[16,128]{1,0} all-reduce(%p0), channel_id=2, replica_groups=[1,256]<=[256], to_apply=%add
  %cp = f32[16,128]{1,0} collective-permute(%p0), channel_id=3, source_target_pairs={{0,1},{1,0}}
  %a2a = (f32[4,128]{1,0}, f32[4,128]{1,0}) all-to-all(%p0, %p0), channel_id=4, replica_groups=[64,4]<=[256]
  ROOT %out = f32[16,128]{1,0} add(%ar, %cp)
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[16,128]{1,0}") == 16 * 128 * 4
    assert _shape_bytes("bf16[8]") == 16
    assert _shape_bytes("(f32[2,2]{1,0}, bf16[4]{0})") == 16 + 8
    assert _shape_bytes("pred[100]") == 100
    assert _shape_bytes("token[]") == 0


def test_parse_def_variants():
    name, shape, op, operands = _parse_def(
        "  %all-gather.93 = f32[2048]{0} all-gather(%x.1), channel_id=2")
    assert name == "all-gather.93" and op == "all-gather"
    assert "%x.1" in operands
    # tuple-shaped with comments
    name, shape, op, _ = _parse_def(
        "  %a = (f32[1]{0}, /*index=1*/f32[1]{0}) all-to-all(%b, %c), x=1")
    assert op == "all-to-all" and _shape_bytes(shape) == 8


def test_participants():
    assert _participants("replica_groups=[16,16]<=[256]") == 16
    assert _participants("replica_groups={{0,1,2,3}}") == 4
    assert _participants("no groups here") == 2


def test_wire_multipliers():
    assert _wire_multiplier("all-reduce", 2) == pytest.approx(1.0)
    assert _wire_multiplier("all-reduce", 256) == pytest.approx(2 * 255 / 256)
    assert _wire_multiplier("all-gather", 16) == 15.0
    assert _wire_multiplier("reduce-scatter", 4) == pytest.approx(0.75)
    assert _wire_multiplier("collective-permute", 8) == 1.0
    assert _wire_multiplier("all-reduce", 1) == 0.0


def test_parse_collective_bytes_end_to_end():
    r = parse_collective_bytes(HLO)
    sz = 16 * 128 * 4
    assert r["by_op"]["all-gather"] == sz
    assert r["by_op"]["all-reduce"] == sz
    assert r["by_op"]["collective-permute"] == sz
    assert r["by_op"]["all-to-all"] == 2 * sz
    assert r["counts"] == {"all-gather": 1, "all-reduce": 1,
                           "collective-permute": 1, "all-to-all": 1}
    # wire: ag over 16 => (16-1)*sz; ar over 256 => 2*255/256*sz
    assert r["wire_by_op"]["all-gather"] == 15 * sz
    assert r["wire_by_op"]["all-reduce"] == int(2 * 255 / 256 * sz)


def test_roofline_terms_and_dominance():
    rec = {"chips": 256,
           "cost": {"flops": 197e12, "bytes_accessed": 819e9 * 2},
           "collectives": {"total": 1, "wire_total": 50e9 * 0.5},
           "model_flops": 197e12 * 256 * 0.5}
    t = terms_from_record(rec)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(2.0)
    assert t.collective_s == pytest.approx(0.5)
    assert t.dominant == "memory"
    assert t.useful_ratio == pytest.approx(0.5)
    # roofline fraction = (useful/total compute) * compute / bound
    assert t.roofline_fraction == pytest.approx(0.5 * 1.0 / 2.0)


def test_model_flops_kinds():
    class Cfg:
        moe = None
    assert model_flops(Cfg, "train", 1024, 8, 1_000_000) == \
        6.0 * 1_000_000 * 1024 * 8
    assert model_flops(Cfg, "prefill", 1024, 8, 10) == 2.0 * 10 * 8192
    assert model_flops(Cfg, "decode", 1024, 8, 10) == 2.0 * 10 * 8


def test_count_params_moe_active():
    from repro.analysis.roofline import count_params
    from repro.configs import get_reduced
    cfg = get_reduced("qwen3-moe-30b-a3b")
    total, active = count_params(cfg)
    assert active < total  # experts discounted by top_k / n_experts
    dense_total, dense_active = count_params(get_reduced("olmo-1b"))
    assert dense_total == dense_active
