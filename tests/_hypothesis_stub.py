"""Tiny deterministic stand-in for ``hypothesis`` (see conftest.py).

Installed into ``sys.modules`` only when the real package is missing, so
``from hypothesis import given, settings, strategies as st`` keeps working
and the property tests still run — each as a fixed-seed sweep of a handful
of drawn examples rather than a shrinking search.  Only the strategy
surface these tests use is provided (``integers``, ``sampled_from``).
"""
from __future__ import annotations

import inspect
import types
import zlib

import numpy as np

#: fallback sweep size; the real library's max_examples is honored up to
#: this cap so the no-deps path stays fast.
MAX_EXAMPLES_CAP = 10


class SearchStrategy:
    def __init__(self, draw):
        self._draw = draw


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)))


def sampled_from(elements) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(
        lambda rng: elements[int(rng.integers(0, len(elements)))])


def given(**strategies):
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = min(getattr(wrapper, "_stub_max_examples",
                            MAX_EXAMPLES_CAP), MAX_EXAMPLES_CAP)
            rng = np.random.default_rng(
                zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                draw = {name: s._draw(rng)
                        for name, s in strategies.items()}
                fn(*args, **draw, **kwargs)
        # Present the signature minus the drawn params (and without
        # ``__wrapped__``) so pytest doesn't look for same-named fixtures.
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(
            parameters=[p for name, p in sig.parameters.items()
                        if name not in strategies])
        return wrapper
    return deco


def settings(max_examples: int = MAX_EXAMPLES_CAP, deadline=None, **_):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


strategies = types.ModuleType("hypothesis.strategies")
strategies.SearchStrategy = SearchStrategy
strategies.integers = integers
strategies.sampled_from = sampled_from
