"""Pallas flash-attention kernel vs pure-jnp oracle: shape/dtype sweeps,
gradient checks, and hypothesis property tests on the combine rule."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def t(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


SWEEP = [
    # b, lq, lk, hq, hkv, d, causal, window, softcap
    (2, 64, 64, 4, 4, 32, True, None, 0.0),
    (1, 48, 80, 4, 2, 24, True, None, 0.0),      # GQA + rectangular + pad
    (1, 33, 100, 6, 3, 40, True, None, 0.0),     # odd lengths
    (2, 16, 96, 4, 4, 32, True, None, 0.0),      # ring-like short q
    (1, 32, 32, 2, 2, 16, False, None, 30.0),    # softcap, non-causal
    (2, 64, 64, 4, 1, 32, True, 16, 0.0),        # MQA + sliding window
    (1, 64, 64, 8, 2, 64, True, 8, 25.0),        # window + softcap + GQA
    (1, 128, 128, 2, 2, 128, True, None, 0.0),   # MXU-aligned
]


@pytest.mark.parametrize("case", SWEEP, ids=[str(i) for i in range(len(SWEEP))])
def test_fwd_matches_oracle(case):
    b, lq, lk, hq, hkv, d, causal, window, cap = case
    q, k, v = t((b, lq, hq, d)), t((b, lk, hkv, d)), t((b, lk, hkv, d))
    o_ref, lse_ref = ref.attention_ref(q, k, v, causal=causal,
                                       window=window, softcap=cap)
    o_p, lse_p = ops.flash_fwd_chunk(q, k, v, causal=causal, window=window,
                                     softcap=cap, impl="pallas_interpret",
                                     block_q=32, block_k=32)
    np.testing.assert_allclose(o_p, o_ref, atol=2e-5, rtol=2e-5)
    mask = lse_ref > ref.NEG_INF / 2
    np.testing.assert_allclose(np.where(mask, lse_p, 0.0),
                               np.where(mask, lse_ref, 0.0),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("case", SWEEP[:6],
                         ids=[str(i) for i in range(6)])
def test_bwd_matches_oracle(case):
    b, lq, lk, hq, hkv, d, causal, window, cap = case
    q, k, v = t((b, lq, hq, d)), t((b, lk, hkv, d)), t((b, lk, hkv, d))

    def loss_ref(q, k, v):
        return (ref.attention_ref(q, k, v, causal=causal, window=window,
                                  softcap=cap)[0] ** 2).sum()

    def loss_pal(q, k, v):
        return (ops.flash_attention(q, k, v, causal=causal, window=window,
                                    softcap=cap, impl="pallas_interpret",
                                    block_q=32, block_k=32) ** 2).sum()

    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    g_pal = jax.grad(loss_pal, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g_pal, g_ref):
        np.testing.assert_allclose(a, b_, atol=5e-4, rtol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    q, k, v = (t((1, 64, 4, 32), dtype) for _ in range(3))
    o_ref, _ = ref.attention_ref(q, k, v, causal=True)
    o_p, _ = ops.flash_fwd_chunk(q, k, v, causal=True,
                                 impl="pallas_interpret",
                                 block_q=32, block_k=32)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o_p, np.float32),
                               np.asarray(o_ref, np.float32),
                               atol=tol, rtol=tol)


def test_chunk_bwd_matches_ref():
    q, k, v = t((1, 32, 4, 16)), t((1, 48, 2, 16)), t((1, 48, 2, 16))
    out, lse = ref.attention_ref(q, k, v, causal=True)
    do = t(out.shape)
    a = ops.flash_bwd_chunk(q, k, v, out, lse, do, causal=True,
                            impl="pallas_interpret", block_q=16, block_k=16)
    b = ref.attention_bwd_ref(q, k, v, out, lse, do, causal=True)
    for x, y in zip(a, b):
        np.testing.assert_allclose(x, y, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# Property tests
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(lk1=st.integers(1, 24), lk2=st.integers(1, 24),
       seed=st.integers(0, 2 ** 16))
def test_combine_equals_joint(lk1, lk2, seed):
    """Attention over concat(K1, K2) == lse-combine of the two partials —
    the invariant ring attention and flash-decoding rely on."""
    rng = np.random.default_rng(seed)
    b, lq, h, d = 1, 8, 2, 8
    q = jnp.asarray(rng.standard_normal((b, lq, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, lk1 + lk2, h, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, lk1 + lk2, h, d)), jnp.float32)
    o_joint, lse_joint = ref.attention_ref(q, k, v)
    p1 = ref.attention_ref(q, k[:, :lk1], v[:, :lk1])
    p2 = ref.attention_ref(q, k[:, lk1:], v[:, lk1:])
    o_c, lse_c = ref.combine_attention([p1, p2])
    np.testing.assert_allclose(o_c, o_joint, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(lse_c, lse_joint, atol=1e-5, rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 5), seed=st.integers(0, 2 ** 16))
def test_combine_order_invariance(n, seed):
    """The combine is associative/commutative over KV chunks."""
    rng = np.random.default_rng(seed)
    b, lq, h, d, lk = 1, 4, 1, 8, 6
    q = jnp.asarray(rng.standard_normal((b, lq, h, d)), jnp.float32)
    parts = []
    for _ in range(n):
        k = jnp.asarray(rng.standard_normal((b, lk, h, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, lk, h, d)), jnp.float32)
        parts.append(ref.attention_ref(q, k, v))
    fwd = ref.combine_attention(parts)
    rev = ref.combine_attention(parts[::-1])
    np.testing.assert_allclose(fwd[0], rev[0], atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(fwd[1], rev[1], atol=1e-5, rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16), window=st.integers(1, 20))
def test_window_is_band_subset(seed, window):
    """Sliding-window output == dense attention with a banded mask."""
    rng = np.random.default_rng(seed)
    b, l, h, d = 1, 16, 2, 8
    q = jnp.asarray(rng.standard_normal((b, l, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, l, h, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, l, h, d)), jnp.float32)
    o_win, _ = ref.attention_ref(q, k, v, causal=True, window=window)
    # manual band mask via bias
    qi = np.arange(l)[:, None]
    kj = np.arange(l)[None, :]
    bias = np.where((kj <= qi) & (kj >= qi - window + 1), 0.0, -1e30)
    o_bias, _ = ref.attention_ref(q, k, v,
                                  bias=jnp.asarray(bias)[None, None])
    np.testing.assert_allclose(o_win, o_bias, atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# Scalar-prefetch band masks: traced offsets stay on the Pallas kernel
# ---------------------------------------------------------------------------

BAND_SWEEP = [
    # b, lq, lk, hq, hkv, d, window, softcap
    (1, 32, 48, 4, 4, 16, None, 0.0),
    (1, 32, 48, 4, 2, 16, None, 0.0),     # GQA
    (2, 24, 40, 4, 1, 24, None, 0.0),     # MQA + padding
    (1, 32, 48, 4, 2, 16, 12, 0.0),       # sliding window
    (1, 32, 48, 4, 2, 16, None, 20.0),    # softcap
    (1, 32, 48, 6, 3, 16, 10, 25.0),      # window + softcap + GQA
]


@pytest.mark.parametrize("case", BAND_SWEEP,
                         ids=[str(i) for i in range(len(BAND_SWEEP))])
def test_fwd_chunk_traced_mask_offset(case):
    """A *traced* mask_offset must dispatch to the Pallas kernel (no
    flashref downgrade) and match the oracle."""
    b, lq, lk, hq, hkv, d, window, cap = case
    q, k, v = t((b, lq, hq, d)), t((b, lk, hkv, d)), t((b, lk, hkv, d))

    @jax.jit
    def f(off):
        return ops.flash_fwd_chunk(q, k, v, causal=True, window=window,
                                   softcap=cap, mask_offset=off,
                                   impl="pallas_interpret",
                                   block_q=16, block_k=16)

    for off in (16, 0, 40):
        o_p, lse_p = f(jnp.int32(off))
        o_ref, lse_ref = ref.attention_ref(q, k, v, causal=True,
                                           window=window, softcap=cap,
                                           mask_offset=off)
        np.testing.assert_allclose(o_p, o_ref, atol=1e-4, rtol=1e-4)
        mask = lse_ref > ref.NEG_INF / 2
        assert ((np.asarray(lse_p) > ref.NEG_INF / 2) == mask).all()
        np.testing.assert_allclose(np.where(mask, lse_p, 0.0),
                                   np.where(mask, lse_ref, 0.0),
                                   atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("case", BAND_SWEEP,
                         ids=[str(i) for i in range(len(BAND_SWEEP))])
def test_bwd_chunk_traced_mask_offset(case):
    b, lq, lk, hq, hkv, d, window, cap = case
    q, k, v = t((b, lq, hq, d)), t((b, lk, hkv, d)), t((b, lk, hkv, d))
    out, lse = ref.attention_ref(q, k, v, causal=True, window=window,
                                 softcap=cap, mask_offset=16)
    do = t(out.shape)

    @jax.jit
    def g(off):
        return ops.flash_bwd_chunk(q, k, v, out, lse, do, causal=True,
                                   window=window, softcap=cap,
                                   mask_offset=off, impl="pallas_interpret",
                                   block_q=16, block_k=16)

    g_p = g(jnp.int32(16))
    g_ref = ref.attention_bwd_ref(q, k, v, out, lse, do, causal=True,
                                  window=window, softcap=cap, mask_offset=16)
    for a, b_ in zip(g_p, g_ref):
        assert a.shape == b_.shape
        np.testing.assert_allclose(a, b_, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("window,cap", [(None, 0.0), (6, 0.0), (None, 20.0)])
def test_zigzag_band_all_step_pairs(window, cap):
    """One kernel call per ring step: the zigzag BandMask must reproduce
    every (i, j) case — diagonal, past, future — for fwd and bwd."""
    from repro.kernels.ref import BandMask
    c, cp = 8, 4
    q, k, v = t((1, 16, 4, 16)), t((1, 16, 2, 16)), t((1, 16, 2, 16))

    @jax.jit
    def f(i, j):
        return ops.flash_fwd_chunk(q, k, v, causal=True, window=window,
                                   softcap=cap,
                                   band=BandMask.zigzag(i, j, c, cp),
                                   impl="pallas_interpret",
                                   block_q=8, block_k=8)

    @jax.jit
    def g(i, j, out, lse, do):
        return ops.flash_bwd_chunk(q, k, v, out, lse, do, causal=True,
                                   window=window, softcap=cap,
                                   band=BandMask.zigzag(i, j, c, cp),
                                   impl="pallas_interpret",
                                   block_q=8, block_k=8)

    for i in range(cp):
        for j in range(cp):
            band = BandMask.zigzag(i, j, c, cp)
            o_ref, lse_ref = ref.attention_ref(q, k, v, causal=True,
                                               window=window, softcap=cap,
                                               band=band)
            o_p, lse_p = f(jnp.int32(i), jnp.int32(j))
            np.testing.assert_allclose(o_p, o_ref, atol=1e-4, rtol=1e-4,
                                       err_msg=f"fwd i={i} j={j}")
            mask = np.asarray(lse_ref) > ref.NEG_INF / 2
            assert ((np.asarray(lse_p) > ref.NEG_INF / 2) == mask).all(), \
                (i, j)
            do = t(o_ref.shape)
            g_p = g(jnp.int32(i), jnp.int32(j), o_ref, lse_ref, do)
            g_ref = ref.attention_bwd_ref(q, k, v, o_ref, lse_ref, do,
                                          causal=True, window=window,
                                          softcap=cap, band=band)
            for a, b_ in zip(g_p, g_ref):
                np.testing.assert_allclose(a, b_, atol=1e-4, rtol=1e-4,
                                           err_msg=f"bwd i={i} j={j}")


def test_bwd_gqa_no_expanded_kv():
    """The GQA backward must not allocate group-expanded K/V: no
    intermediate of shape (B*Hq, Lk_pad, D_pad) may appear in the jaxpr."""
    b, lq, lk, hq, hkv, d = 1, 32, 48, 4, 2, 16
    q, k, v = t((b, lq, hq, d)), t((b, lk, hkv, d)), t((b, lk, hkv, d))
    out, lse = ref.attention_ref(q, k, v, causal=True)
    do = t(out.shape)

    def g(q, k, v, out, lse, do):
        return ops.flash_bwd_chunk(q, k, v, out, lse, do, causal=True,
                                   impl="pallas_interpret",
                                   block_q=16, block_k=16)

    jaxpr = jax.make_jaxpr(g)(q, k, v, out, lse, do)
    lk_pad, d_pad = 48, 128
    expanded = (b * hq, lk_pad, d_pad)      # what jnp.repeat used to make

    def shapes(jp):
        for eqn in jp.eqns:
            for var in eqn.outvars:
                yield tuple(getattr(var.aval, "shape", ()))
            for sub in eqn.params.values():
                if hasattr(sub, "jaxpr"):
                    yield from shapes(sub.jaxpr)

    assert expanded not in set(shapes(jaxpr.jaxpr))
    dq, dk, dv = g(q, k, v, out, lse, do)
    assert dk.shape == k.shape and dv.shape == v.shape
