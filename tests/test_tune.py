"""PlanTuner: search-space feasibility, the paper's placement-crossover
ordering, winner optimality, TunedPlan round-trip through build_plan, and
the shared cost-model surface."""
import json

import jax
import pytest

from repro.analysis.cost import (AttnCase, CostConstants, V5E,
                                 attention_op_time, end_to_end_mfu)
from repro.configs import get_reduced
from repro.core.plan import build_plan, plan_memory
from repro.core.topology import ParallelConfig
from repro.tune import TunedPlan, enumerate_space, tune
from repro.tune.space import hp_choices, seq_ok


class FakeDev:
    def __init__(self, i):
        self.id = i

    def __repr__(self):
        return f"d{self.id}"


def _fake_devs(n):
    return [FakeDev(i) for i in range(n)]


CFG = get_reduced("qwen3-1.7b")          # 4 q-heads, 2 kv-heads


# ---------------------------------------------------------------------------
# stage 1: enumeration respects the hard constraints + the memory model
# ---------------------------------------------------------------------------

def test_space_is_feasible():
    """Every enumerated candidate validates, divides, shards the batch,
    and fits the plan memory model — no infeasible point reaches
    scoring (and so none can win)."""
    cands = enumerate_space(CFG, num_devices=8, seq_len=256,
                            global_batch=8, memory_budget_gb=1.0)
    assert cands
    for c in cands:
        c.pc.validate()
        pc = c.pc
        assert pc.num_devices == 8
        assert CFG.n_heads % pc.hp == 0
        if pc.hp > CFG.n_kv_heads:
            assert pc.hp % CFG.n_kv_heads == 0
        assert 256 % pc.sp == 0
        if pc.cp > 1:
            assert (256 // pc.cp) % 2 == 0          # zigzag half-chunks
        assert 8 % c.grad_accum == 0
        assert (8 // c.grad_accum) % (pc.pods * pc.dp) == 0
        assert c.remat in ("none", "scpp", "full")
        assert c.mem["fits"], c.tag
        # the candidate's memory verdict is *the* build_plan model
        _, _, _, mem = plan_memory(
            CFG, pc, grad_accum=c.grad_accum, remat=c.remat, zero=c.zero,
            memory_budget_gb=1.0, seq_len=256, global_batch=8)
        assert mem["total_dev"] == c.mem["total_dev"]


def test_space_contains_the_degenerate_corners():
    """DeepSpeed-Ulysses (hp=sp) and Megatron-CP (cp=sp) are corners of
    the enumerated space, not separate systems."""
    cands = enumerate_space(CFG, num_devices=8, seq_len=256,
                            global_batch=8, dp=2, memory_budget_gb=1.0)
    splits = {(c.pc.hp, c.pc.cp) for c in cands}
    assert (4, 1) in splits                  # Ulysses corner (hp=sp=4)
    assert (1, 4) in splits                  # Megatron-CP corner
    assert (2, 2) in splits                  # a true 2D point


def test_hp_choices_respect_gqa_replication():
    import dataclasses
    # heads=4, kv=2: hp=4 needs 4 % 2 == 0 (KV replication) -> allowed;
    # a 3-way split never divides the head count.
    assert hp_choices(CFG, 4) == [1, 2, 4]
    assert 3 not in hp_choices(CFG, 6)
    # below H_kv the KV heads shard over hp: 6 kv heads cannot split 4
    # ways even though 24 q heads can.
    odd = dataclasses.replace(CFG, n_heads=24, n_kv_heads=6)
    assert 4 not in hp_choices(odd, 4)
    assert hp_choices(odd, 12) == [1, 2, 3, 6, 12]


def test_seq_divisibility_gates_zigzag():
    assert seq_ok(CFG, 4, 4, 256)
    assert not seq_ok(CFG, 3, 3, 256)        # 256 % 3 != 0
    assert not seq_ok(CFG, 256, 256, 256)    # per-rank chunk of 1: no halves


def test_degenerate_placement_deduped():
    """hp==1 / cp==1 grids have one physical device order; only the
    canonical placement is enumerated there."""
    cands = enumerate_space(CFG, num_devices=8, seq_len=256,
                            global_batch=8, dp=2, memory_budget_gb=1.0)
    for c in cands:
        if c.pc.cp == 1:
            assert c.pc.placement == "head_first"
        elif c.pc.hp == 1:
            assert c.pc.placement == "context_first"


# ---------------------------------------------------------------------------
# stage 2: the analytic ranking reproduces the paper's placement analysis
# ---------------------------------------------------------------------------

def _attn_time(h_kv, s, hp, placement, sp=64):
    c = AttnCase(s=s, h_kv=h_kv, sp=sp, hp=hp, placement=placement)
    return attention_op_time(c) + attention_op_time(c, backward=True)


def test_placement_crossover_head_vs_context_first():
    """The §4.4 analysis, executable: at 128k MHA on 64-way SP,
    context-first wins the ring-dominated low-hp points and head-first
    wins once the SeqAlltoAll dominates (hp >= 8) — the crossover the
    paper's Table 3 placement columns show."""
    for hp in (2, 4):
        assert _attn_time(32, 131072, hp, "context_first") < \
            _attn_time(32, 131072, hp, "head_first"), hp
    for hp in (8, 16, 32):
        assert _attn_time(32, 131072, hp, "head_first") < \
            _attn_time(32, 131072, hp, "context_first"), hp
    # GQA's small KV chunks never let the rings dominate: head-first
    # wins the whole hp sweep (the paper's GQA rows).
    for hp in (2, 4, 8, 16, 32):
        assert _attn_time(8, 131072, hp, "head_first") < \
            _attn_time(8, 131072, hp, "context_first"), hp


def test_interior_2d_point_beats_both_corners():
    """Table-2 shape: MHA at 128k on 32-way SP — a 2D split (hp=4)
    out-MFUs both DeepSpeed-Ulysses (hp=sp) and pure ring-CP (hp=1)."""
    mfu = {hp: end_to_end_mfu(AttnCase(s=131072, h_kv=32, sp=32, hp=hp))
           for hp in (1, 4, 32)}
    assert mfu[4] > mfu[1]
    assert mfu[4] > mfu[32]


def test_winner_is_the_analytic_minimum():
    r = tune(CFG, num_devices=8, seq_len=256, global_batch=8,
             memory_budget_gb=1.0)
    assert r.ranked
    assert r.winner.score_s == min(s.score_s for s in r.ranked)
    assert r.winner.cand.mem["fits"]
    assert r.space_size == len(r.ranked)


def test_calibrated_constants_rescale_not_reorder():
    """A uniform bandwidth/flops rescale must not change the placement
    ordering (the trade-off is a bw *ratio*)."""
    const = CostConstants(peak=V5E.peak / 50, hbm=V5E.hbm / 50,
                          ici=V5E.ici / 50, source="test")
    c_hf = AttnCase(s=131072, h_kv=32, sp=64, hp=2,
                    placement="head_first")
    c_cf = AttnCase(s=131072, h_kv=32, sp=64, hp=2,
                    placement="context_first")
    assert attention_op_time(c_cf, const=const) < \
        attention_op_time(c_hf, const=const)


# ---------------------------------------------------------------------------
# TunedPlan round-trip through build_plan
# ---------------------------------------------------------------------------

def test_tuned_plan_roundtrips_through_build_plan(tmp_path):
    r = tune(CFG, num_devices=8, seq_len=256, global_batch=8,
             memory_budget_gb=1.0)
    tp = r.tuned_plan()
    path = tp.save(str(tmp_path / "plan.json"))
    loaded = TunedPlan.load(path)
    assert loaded == tp

    devs = _fake_devs(8)
    via_tuned = build_plan(CFG, devices=devs, tuned=loaded)
    explicit = build_plan(CFG, loaded.parallel(), devices=devs,
                          grad_accum=loaded.grad_accum,
                          remat=loaded.remat, zero=loaded.zero,
                          seq_len=loaded.seq_len,
                          global_batch=loaded.global_batch)
    assert via_tuned.pc == explicit.pc == tp.parallel()
    assert via_tuned.grad_accum == explicit.grad_accum == tp.grad_accum
    assert via_tuned.cfg.remat == explicit.cfg.remat == tp.remat
    assert via_tuned.zero_mode == explicit.zero_mode
    assert via_tuned.zero_groups == explicit.zero_groups
    assert via_tuned.mem == explicit.mem
    assert via_tuned.seq_len == tp.seq_len


def test_tuned_plan_defaults_lose_to_explicit_args(tmp_path):
    tp = TunedPlan(arch="x", num_devices=4, seq_len=256, global_batch=8,
                   dp=2, hp=2, grad_accum=2, remat="full", zero="dp")
    plan = build_plan(CFG, devices=_fake_devs(4), tuned=tp,
                      grad_accum=4, remat="none", seq_len=128,
                      global_batch=16)
    assert plan.grad_accum == 4              # explicit beats tuned
    assert plan.cfg.remat == "none"
    assert plan.seq_len == 128 and plan.global_batch == 16
    assert plan.pc == tp.parallel()          # pc still from the file
    # explicitly passing the library default (1 / "auto") also wins
    plan1 = build_plan(CFG, devices=_fake_devs(4), tuned=tp,
                       grad_accum=1, zero="auto", seq_len=256,
                       global_batch=8)
    assert plan1.grad_accum == 1
    assert plan1.zero_mode == "replica"      # auto on a tiny model


def test_tuned_plan_json_is_versioned_and_forward_safe(tmp_path):
    tp = TunedPlan(arch="x", num_devices=1, seq_len=64, global_batch=4)
    d = tp.to_json()
    d["some_future_field"] = 123             # unknown keys are dropped
    assert TunedPlan.from_json(d) == tp
    with open(tmp_path / "future.json", "w") as f:
        json.dump({**d, "version": 99}, f)
    with pytest.raises(AssertionError):
        TunedPlan.load(str(tmp_path / "future.json"))


# ---------------------------------------------------------------------------
# shared cost model surface
# ---------------------------------------------------------------------------

def test_attncase_from_plan():
    pc = ParallelConfig(dp=2, hp=2, cp_outer=1, cp_inner=2)
    plan = build_plan(CFG, pc, devices=_fake_devs(8), seq_len=256,
                      global_batch=8)
    c = AttnCase.from_plan(plan)
    assert (c.s, c.d, c.h, c.h_kv) == (256, CFG.d_model, CFG.n_heads,
                                       CFG.n_kv_heads)
    assert (c.sp, c.hp, c.w, c.placement) == (4, 2, 2, "head_first")
    assert c.cp == 2


def test_analytic_shim_deprecated_but_identical():
    import importlib
    import warnings
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        import benchmarks.analytic as shim
        shim = importlib.reload(shim)       # re-fire the import warning
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    from repro.analysis import cost
    assert shim.AttnCase is cost.AttnCase
    assert shim.attention_op_time is cost.attention_op_time
    assert shim.PEAK == cost.PEAK and shim.ICI == cost.ICI
    from repro.analysis import roofline
    assert roofline.PEAK_FLOPS == cost.PEAK
    assert roofline.ICI_BW == cost.ICI


# ---------------------------------------------------------------------------
# stage 3: live measurement (1-device, reduced config — cheap)
# ---------------------------------------------------------------------------

def test_measure_top_reranks_with_wall_clock():
    r = tune(CFG, num_devices=1, seq_len=64, global_batch=2,
             memory_budget_gb=1.0, measure_top_k=1, measure_steps=1,
             accums=(1,), remats=("none",), zeros=("replica",))
    w = r.winner
    assert w.measured_s is not None and w.measured_s > 0
    assert r.ranked[0] is w                  # re-ranked measured-first
    tp = r.tuned_plan()
    assert tp.measured_s == w.measured_s
    # a measured winner still builds + runs
    plan = build_plan(CFG, devices=jax.devices()[:1], tuned=tp)
    assert plan.pc == tp.parallel()


# ---------------------------------------------------------------------------
# FPDT chunk-offload candidates
# ---------------------------------------------------------------------------

def test_chunks_ok_divisibility_and_zigzag():
    from repro.tune.space import chunks_ok
    pc = ParallelConfig(dp=1, hp=2, cp_outer=2, cp_inner=2)   # sp=8, cp=4
    assert chunks_ok(CFG, pc, 1024, 4)        # sc=256 shards fine
    assert not chunks_ok(CFG, pc, 1024, 3)    # 1024 % 3 != 0
    assert not chunks_ok(CFG, pc, 1024, 256)  # sc=4 < sp
    # zigzag needs an even per-cp-rank sub-chunk: sc=6 over cp=2 is odd
    pc2 = ParallelConfig(dp=1, hp=1, cp_outer=2, cp_inner=1)
    assert not chunks_ok(CFG, pc2, 24, 4)
    assert chunks_ok(CFG, pc2, 32, 4)


def test_offload_candidates_only_when_resident_infeasible():
    """Offload points appear exactly where they help: the resident twin
    must not fit (activations over budget) while the state does — and a
    short-sequence, ample-budget space stays fully resident."""
    cands = enumerate_space(CFG, num_devices=8, seq_len=131072,
                            global_batch=8, memory_budget_gb=0.05)
    offs = [c for c in cands if c.offload_chunks > 1]
    assert offs, "no offload candidates at the infeasible long-seq point"
    for c in offs:
        assert c.mem["fits"] and c.mem["fits_state"]
        assert c.tag.endswith(f".off{c.offload_chunks}")
        _, _, _, mem_r = plan_memory(
            CFG, c.pc, grad_accum=c.grad_accum, remat=c.remat,
            zero=c.zero, memory_budget_gb=0.05, seq_len=131072,
            global_batch=8)
        assert not mem_r["fits"], c.tag       # the resident twin does not fit
    easy = enumerate_space(CFG, num_devices=8, seq_len=256,
                           global_batch=8, memory_budget_gb=1.0)
    assert easy and all(c.offload_chunks == 1 for c in easy)


def test_tuner_prefers_offload_when_resident_infeasible():
    r = tune(CFG, num_devices=8, seq_len=131072, global_batch=8,
             memory_budget_gb=0.05)
    w = r.winner.cand
    assert w.offload_chunks > 1
    tp = r.tuned_plan()
    assert tp.offload_chunks == w.offload_chunks
    plan = build_plan(CFG, devices=_fake_devs(8), tuned=tp,
                      seq_len=131072, global_batch=8,
                      memory_budget_gb=0.05)
    assert plan.offload_chunks == tp.offload_chunks


def test_tuner_stays_resident_when_it_fits():
    r = tune(CFG, num_devices=8, seq_len=256, global_batch=8,
             memory_budget_gb=1.0)
    assert r.winner.cand.offload_chunks == 1
    assert r.tuned_plan().offload_chunks == 1


def test_tuned_plan_v1_file_loads_with_resident_default(tmp_path):
    tp = TunedPlan(arch="x", num_devices=4, seq_len=256, global_batch=8)
    d = tp.to_json()
    d.pop("offload_chunks")                   # a pre-offload (v1) file
    d["version"] = 1
    with open(tmp_path / "v1.json", "w") as f:
        json.dump(d, f)
    loaded = TunedPlan.load(str(tmp_path / "v1.json"))
    assert loaded.version == 1
    assert loaded.offload_chunks == 1         # defaults to resident
