"""Distributed equivalence checks, run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (device count is locked
at first jax import, so these cannot run inside the main pytest process).

Usage:  python tests/_dist_checks.py <check-name>
Prints ``PASS <name>`` on success; any assertion raises.
"""
import os
import sys

if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np          # noqa: E402
import jax                  # noqa: E402
import jax.numpy as jnp     # noqa: E402


def err(a, b):
    return float(np.abs(np.asarray(a, np.float64)
                        - np.asarray(b, np.float64)).max())


def _runtimes(pc):
    from repro.core.runtime import Runtime
    from repro.core.topology import ParallelConfig, make_mesh
    mesh = make_mesh(pc)
    rt = Runtime(mesh=mesh, pc=pc, impl="ref")
    pc0 = ParallelConfig()
    mesh0 = make_mesh(pc0, devices=jax.devices()[:1])
    rt0 = Runtime(mesh=mesh0, pc=pc0, impl="ref")
    return rt, rt0


def check_attention_grid():
    from repro.core.topology import ParallelConfig, make_mesh
    from repro.core.attention2d import Attn2DConfig, attention_2d
    from repro.core.zigzag import to_zigzag, from_zigzag
    from repro.kernels.ref import attention_ref

    rng = np.random.default_rng(1)
    B, S, H, HKV, D = 2, 64, 8, 4, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, HKV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, HKV, D)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)

    def oracle(q, k, v):
        out, _ = attention_ref(q, k, v, causal=True)
        return (out * w).sum(), out

    (_, o_ref), g_ref = jax.value_and_grad(
        oracle, argnums=(0, 1, 2), has_aux=True)(q, k, v)

    grids = [(1, 1, 1, 4, "head_first"), (1, 1, 2, 2, "context_first"),
             (1, 2, 2, 2, "head_first"), (1, 4, 1, 2, "head_first"),
             (1, 8, 1, 1, "head_first"), (2, 2, 2, 1, "context_first"),
             (2, 1, 1, 2, "head_first")]
    for dp, hp, no, wi, placement in grids:
        pc = ParallelConfig(dp=dp, hp=hp, cp_outer=no, cp_inner=wi,
                            placement=placement)
        mesh = make_mesh(pc)
        cp = pc.cp
        cfg = Attn2DConfig(hp=hp, n_out=no, w=wi, causal=True, impl="ref")

        def dist(q, k, v):
            qz, kz, vz = (to_zigzag(x, cp) for x in (q, k, v))
            with mesh:
                out = attention_2d(qz, kz, vz, mesh=mesh, cfg=cfg)
            out = from_zigzag(out, cp)
            return (out * w).sum(), out

        with mesh:
            (_, o_d), g_d = jax.value_and_grad(
                dist, argnums=(0, 1, 2), has_aux=True)(q, k, v)
        assert err(o_d, o_ref) < 5e-6, (hp, no, wi, err(o_d, o_ref))
        for a, b in zip(g_d, g_ref):
            assert err(a, b) < 5e-6, (hp, no, wi)
    print("PASS attention_grid")


def check_attention_modes():
    from repro.core.topology import ParallelConfig, make_mesh
    from repro.core.attention2d import Attn2DConfig, attention_2d
    from repro.core.zigzag import to_zigzag, from_zigzag
    from repro.kernels.ref import attention_ref

    rng = np.random.default_rng(2)
    B, S, H, HKV, D = 1, 96, 4, 2, 8
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, HKV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, HKV, D)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)

    cases = [
        dict(causal=True, zigzag=True, window=20, softcap=0.0,
             hp=2, no=2, wi=2),
        dict(causal=True, zigzag=True, window=None, softcap=25.0,
             hp=2, no=1, wi=2),
        dict(causal=False, zigzag=False, window=None, softcap=0.0,
             hp=2, no=2, wi=2),
        dict(causal=True, zigzag=False, window=None, softcap=0.0,
             hp=1, no=2, wi=2),
        dict(causal=True, zigzag=False, window=12, softcap=0.0,
             hp=2, no=1, wi=2),
    ]
    for c in cases:
        cp = c["no"] * c["wi"]
        pc = ParallelConfig(hp=c["hp"], cp_outer=c["no"], cp_inner=c["wi"])
        mesh = make_mesh(pc)
        cfg = Attn2DConfig(hp=c["hp"], n_out=c["no"], w=c["wi"],
                           causal=c["causal"], zigzag=c["zigzag"],
                           window=c["window"], softcap=c["softcap"],
                           impl="ref")
        zz = c["zigzag"] and c["causal"]

        def oracle(q, k, v):
            out, _ = attention_ref(q, k, v, causal=c["causal"],
                                   window=c["window"], softcap=c["softcap"])
            return (out * w).sum(), out

        def dist(q, k, v):
            if zz:
                q, k, v = (to_zigzag(x, cp) for x in (q, k, v))
            with mesh:
                out = attention_2d(q, k, v, mesh=mesh, cfg=cfg)
            return ((from_zigzag(out, cp) if zz else out) * w).sum(), \
                from_zigzag(out, cp) if zz else out

        (_, o_ref), g_ref = jax.value_and_grad(
            oracle, argnums=(0, 1, 2), has_aux=True)(q, k, v)
        with mesh:
            (_, o_d), g_d = jax.value_and_grad(
                dist, argnums=(0, 1, 2), has_aux=True)(q, k, v)
        assert err(o_d, o_ref) < 5e-6, (c, err(o_d, o_ref))
        for a, b in zip(g_d, g_ref):
            assert err(a, b) < 5e-6, c
    print("PASS attention_modes")


def check_ring_pallas_path():
    """Double-ring 2D-Attention on ``impl="pallas_interpret"``: the traced
    (axis_index-derived) band offsets must stay on the Pallas kernels — the
    jnp fallbacks are poisoned to prove no silent flashref downgrade — and
    out + grads must match the single-device oracle."""
    from repro.core.topology import ParallelConfig, make_mesh
    from repro.core.attention2d import Attn2DConfig, attention_2d
    from repro.core.zigzag import to_zigzag, from_zigzag
    from repro.kernels import ref as ref_mod
    from repro.kernels.ref import attention_ref

    rng = np.random.default_rng(3)
    B, S, H, HKV, D = 1, 64, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, HKV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, HKV, D)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)

    cases = [dict(window=None, softcap=0.0),
             dict(window=12, softcap=20.0)]
    pc = ParallelConfig(dp=1, hp=2, cp_outer=2, cp_inner=2)
    mesh = make_mesh(pc)
    cp = pc.cp

    def boom(*a, **kw):
        raise AssertionError("jnp fallback selected on the ring path")

    poisoned = ("attention_ref_chunked", "attention_bwd_ref_chunked")
    saved = {n: getattr(ref_mod, n) for n in poisoned}
    for case in cases:
        def oracle(q, k, v):
            out, _ = attention_ref(q, k, v, causal=True, **case)
            return (out * w).sum(), out

        (_, o_ref), g_ref = jax.value_and_grad(
            oracle, argnums=(0, 1, 2), has_aux=True)(q, k, v)

        cfg = Attn2DConfig(hp=2, n_out=2, w=2, causal=True,
                           impl="pallas_interpret", **case)

        def dist(q, k, v):
            qz, kz, vz = (to_zigzag(x, cp) for x in (q, k, v))
            with mesh:
                out = attention_2d(qz, kz, vz, mesh=mesh, cfg=cfg)
            out = from_zigzag(out, cp)
            return (out * w).sum(), out

        for n in poisoned:
            setattr(ref_mod, n, boom)
        try:
            with mesh:
                (_, o_d), g_d = jax.value_and_grad(
                    dist, argnums=(0, 1, 2), has_aux=True)(q, k, v)
        finally:
            for n, fn in saved.items():
                setattr(ref_mod, n, fn)
        assert err(o_d, o_ref) < 5e-5, (case, err(o_d, o_ref))
        for a, b in zip(g_d, g_ref):
            assert err(a, b) < 5e-5, case
    print("PASS ring_pallas_path")


def check_ssm():
    from repro.core.topology import ParallelConfig
    from repro.models.ssm import (Mamba1Dims, Mamba2Dims, init_mamba1,
                                  init_mamba2, mamba1_apply, mamba2_apply)
    pc = ParallelConfig(dp=1, hp=2, cp_outer=2, cp_inner=2)
    rt, rt0 = _runtimes(pc)
    key = jax.random.PRNGKey(0)
    B, S, D = 2, 64, 32
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D), jnp.float32)

    m1 = Mamba1Dims(d_model=D, d_inner=2 * D, d_state=8, seg=8)
    p1 = init_mamba1(key, m1)
    y_d = mamba1_apply(p1, x, rt, m1)
    y_s = mamba1_apply(p1, x, rt0, m1)
    assert err(y_d, y_s) < 1e-5
    g_d = jax.grad(lambda x: (mamba1_apply(p1, x, rt, m1) ** 2).sum())(x)
    g_s = jax.grad(lambda x: (mamba1_apply(p1, x, rt0, m1) ** 2).sum())(x)
    assert err(g_d, g_s) < 1e-5

    m2 = Mamba2Dims(d_model=D, d_inner=2 * D, d_state=8, head_dim=8, seg=8)
    p2 = init_mamba2(key, m2)
    y_d = mamba2_apply(p2, x, rt, m2)
    y_s = mamba2_apply(p2, x, rt0, m2)
    assert err(y_d, y_s) < 5e-5
    print("PASS ssm")


def check_moe():
    from repro.core.topology import ParallelConfig
    from repro.models.moe import MoEDims, init_moe, moe_apply
    pc = ParallelConfig(dp=2, hp=2, cp_outer=1, cp_inner=2)
    rt, rt0 = _runtimes(pc)
    B, S, D = 2, 32, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D), jnp.float32)
    m = MoEDims(d_model=D, n_experts=16, top_k=2, d_ff=32, n_shared=1,
                capacity_factor=8.0)
    p = init_moe(jax.random.PRNGKey(0), m)
    y1, _ = moe_apply(p, x, rt, m)
    y0, _ = moe_apply(p, x, rt0, m)
    assert err(y1, y0) < 5e-6
    g1 = jax.grad(lambda p: (moe_apply(p, x, rt, m)[0] ** 2).sum())(p)
    g0 = jax.grad(lambda p: (moe_apply(p, x, rt0, m)[0] ** 2).sum())(p)
    for kk in ("router", "w1", "w2", "w3"):
        assert err(g1[kk], g0[kk]) < 1e-4, kk
    print("PASS moe")


def check_e2e_loss():
    """Full forward_loss on an 8-device 2D mesh == single device, for one
    arch per family (incl. zigzag data layout handling)."""
    from repro.configs import get_reduced
    from repro.core.topology import ParallelConfig
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.models.model import forward_loss, init_params

    for name, grid in [("qwen3-1.7b", (1, 2, 2, 2)),
                       ("gemma2-2b", (2, 2, 1, 2)),
                       ("zamba2-7b", (1, 4, 1, 2)),
                       ("falcon-mamba-7b", (1, 1, 4, 2)),
                       ("deepseek-v2-lite-16b", (1, 4, 2, 1)),
                       ("whisper-small", (1, 4, 1, 2))]:
        dp, hp, no, wi = grid
        cfg = get_reduced(name)
        pc = ParallelConfig(dp=dp, hp=hp, cp_outer=no, cp_inner=wi)
        rt, rt0 = _runtimes(pc)
        params = init_params(cfg, jax.random.PRNGKey(0))
        zz = cfg.zigzag and cfg.family in ("dense", "moe", "encdec")
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64,
                                      global_batch=2, cp=pc.cp, zigzag=zz),
                           cfg)
        batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        data0 = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64,
                                       global_batch=2, cp=1, zigzag=False),
                            cfg)
        batch0 = {k: jnp.asarray(v) for k, v in data0.batch(0).items()}
        with rt.mesh:
            loss_d, _ = forward_loss(params, batch, rt, cfg)
        with rt0.mesh:
            loss_s, _ = forward_loss(params, batch0, rt0, cfg)
        assert abs(float(loss_d) - float(loss_s)) < 1e-3, \
            (name, float(loss_d), float(loss_s))
    print("PASS e2e_loss")


def check_decode_consistency():
    """Distributed prefill + decode == the same logits as single-device."""
    from repro.configs import get_reduced
    from repro.core.topology import ParallelConfig
    from repro.models.decode import decode_step, grow_caches, prefill
    from repro.models.model import init_params

    for name, grid in [("qwen3-1.7b", (1, 2, 2, 1)),
                       ("gemma2-2b", (1, 2, 1, 2)),
                       ("deepseek-v2-lite-16b", (1, 4, 1, 1)),
                       ("falcon-mamba-7b", (1, 1, 2, 2))]:
        dp, hp, no, wi = grid
        cfg = get_reduced(name)
        pc = ParallelConfig(dp=dp, hp=hp, cp_outer=no, cp_inner=wi)
        rt, rt0 = _runtimes(pc)
        params = init_params(cfg, jax.random.PRNGKey(0))
        B, S = 2, 32
        tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                    cfg.vocab)
        batch = {"tokens": tokens}
        if cfg.family == "encdec":
            batch["frames"] = jax.random.normal(
                jax.random.PRNGKey(2), (B, cfg.enc_frames, cfg.d_model))
        with rt.mesh:
            lg_d, caches_d = prefill(params, batch, rt, cfg)
            caches_d = grow_caches(cfg, caches_d, 4)
            nxt = np.asarray(
                jnp.argmax(lg_d[:, -1], axis=-1))[:, None].astype(np.int32)
            lg2_d, _ = decode_step(params, caches_d, jnp.asarray(nxt),
                                   jnp.int32(S), rt, cfg)
        with rt0.mesh:
            lg_s, caches_s = prefill(params, batch, rt0, cfg)
            caches_s = grow_caches(cfg, caches_s, 4)
            lg2_s, _ = decode_step(params, caches_s, jnp.asarray(nxt),
                                   jnp.int32(S), rt0, cfg)
        assert err(lg_d, lg_s) < 1e-3, (name, err(lg_d, lg_s))
        assert err(lg2_d, lg2_s) < 1e-3, (name, err(lg2_d, lg2_s))
    print("PASS decode_consistency")


def check_plan_placement():
    """ExecutionPlan round-trips head_first AND context_first through
    attention_2d with identical numerics (vs the single-device oracle):
    placement only permutes device placement, never the math."""
    from repro.configs import get_reduced
    from repro.core.plan import build_plan
    from repro.core.topology import ParallelConfig
    from repro.core.zigzag import to_zigzag, from_zigzag
    from repro.core.attention2d import attention_2d
    from repro.kernels.ref import attention_ref

    rng = np.random.default_rng(7)
    B, S, H, HKV, D = 1, 64, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, HKV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, HKV, D)), jnp.float32)
    o_ref, _ = attention_ref(q, k, v, causal=True)

    outs = {}
    for placement in ("head_first", "context_first"):
        pc = ParallelConfig(hp=2, cp_outer=2, cp_inner=2,
                            placement=placement)
        plan = build_plan(get_reduced("qwen3-1.7b"), pc, impl="ref")
        cfg2d = plan.attn2d(causal=True, zigzag=True)
        assert (cfg2d.hp, cfg2d.n_out, cfg2d.w) == (2, 2, 2)
        qz, kz, vz = (to_zigzag(x, pc.cp) for x in (q, k, v))
        with plan.mesh:
            out = attention_2d(qz, kz, vz, mesh=plan.mesh, cfg=cfg2d)
        outs[placement] = np.asarray(from_zigzag(out, pc.cp))
        assert err(outs[placement], o_ref) < 5e-6, placement
    assert err(outs["head_first"], outs["context_first"]) == 0.0
    print("PASS plan_placement")


def check_accum_collectives():
    """Gradient accumulation on a dp=2 mesh: (a) the partitioned HLO's
    collective instruction count does not scale with grad_accum (the
    grad reduction/update point is outside the microbatch loop — no
    per-microbatch resharding or optimizer application), and (b) the
    sharded accum=2 step matches the single-device flat step."""
    import re
    from repro.configs import get_reduced
    from repro.core.plan import build_plan
    from repro.core.topology import ParallelConfig
    from repro.data.pipeline import SyntheticLM
    from repro.models.model import init_params
    from repro.train.optimizer import init_opt_state
    from repro.train.train_step import jit_train_step, make_train_step

    cfg = get_reduced("qwen3-1.7b")

    def compile_counts(accum):
        plan = build_plan(cfg, ParallelConfig(dp=2), grad_accum=accum,
                          seq_len=64, global_batch=8, zero="dp",
                          impl="ref")
        p = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
        o = jax.eval_shape(init_opt_state, p)
        p_sh = plan.param_shardings(p)
        shp = (accum, 8 // accum, 64) if accum > 1 else (8, 64)
        batch = {kk: jax.ShapeDtypeStruct(shp, jnp.int32)
                 for kk in ("tokens", "labels", "positions")}
        with plan.mesh:
            fn = jax.jit(make_train_step(plan),
                         in_shardings=(p_sh, plan.opt_shardings(p_sh),
                                       plan.batch_shardings("train")),
                         out_shardings=(p_sh, plan.opt_shardings(p_sh),
                                        None))
            hlo = fn.lower(p, o, batch).compile().as_text()
        return {op: len(re.findall(op + r"[-.\d]*\(", hlo))
                for op in ("all-reduce", "reduce-scatter")}

    c1, c4 = compile_counts(1), compile_counts(4)
    assert c1 == c4, (c1, c4)

    # numerics: dp=2 × accum=2 == single-device flat batch
    results = {}
    for tag, pc, accum in (("dist", ParallelConfig(dp=2), 2),
                           ("single", ParallelConfig(), 1)):
        devs = None if pc.dp > 1 else jax.devices()[:1]
        plan = build_plan(cfg, pc, devices=devs, grad_accum=accum,
                          seq_len=64, global_batch=8, impl="ref")
        data = SyntheticLM(plan.data_config(64, 8), cfg)
        batch = {kk: jnp.asarray(vv) for kk, vv in data.batch(0).items()}
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = init_opt_state(params)
        with plan.mesh:
            step, _, _ = jit_train_step(plan, params, donate=False)
            p2, _, m = step(params, opt, batch)
        results[tag] = (jax.device_get(p2), float(m["loss"]))
    assert abs(results["dist"][1] - results["single"][1]) < 1e-5
    for a, b in zip(jax.tree.leaves(results["dist"][0]),
                    jax.tree.leaves(results["single"][0])):
        assert err(a, b) < 1e-5
    print("PASS accum_collectives")


def check_packed_parity():
    """Packed-document training parity: one packed batch of K documents
    must produce the same loss and parameter gradients as K independent
    unpacked runs (token-weighted aggregate), on a ring (cp>1) config and
    a Ulysses (hp>1) config — and the packed traced step must stay on the
    Pallas kernels (the jnp fallbacks are poisoned: no flashref
    downgrade for the doc-masked path)."""
    import dataclasses as dc
    from repro.configs import get_reduced
    from repro.core.plan import build_plan
    from repro.core.topology import ParallelConfig
    from repro.data.pipeline import PackedLM
    from repro.kernels import ref as ref_mod
    from repro.models.model import forward_loss, init_params

    cfg = dc.replace(get_reduced("qwen3-1.7b"), window=None,
                     window_pattern=0)
    S, B = 64, 2
    params = init_params(cfg, jax.random.PRNGKey(0))

    def boom(*a, **kw):
        raise AssertionError("jnp fallback selected on the packed path")

    poisoned = ("attention_ref_chunked", "attention_bwd_ref_chunked")
    saved = {n: getattr(ref_mod, n) for n in poisoned}

    # single-device per-document oracle (token-weighted aggregation)
    plan0 = build_plan(cfg, ParallelConfig(), devices=jax.devices()[:1],
                       impl="ref", seq_len=S, global_batch=B)

    for pc in (ParallelConfig(dp=1, hp=1, cp_outer=2, cp_inner=2),
               ParallelConfig(dp=1, hp=2, cp_outer=1, cp_inner=1),
               # the full 2D composition: head AlltoAll gathers the doc
               # table, the zigzag ring keeps it stationary
               ParallelConfig(dp=1, hp=2, cp_outer=1, cp_inner=2)):
        plan = build_plan(cfg, pc, impl="pallas_interpret", seq_len=S,
                          global_batch=B, packed=True, mean_doc_len=16)
        data = PackedLM(plan.data_config(S, B, doc_len_range=(10, 38)),
                        cfg)
        batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        grad_of = jax.value_and_grad(
            lambda p, b, rt: forward_loss(p, b, rt, cfg)[0],
            has_aux=False)
        for n in poisoned:
            setattr(ref_mod, n, boom)
        try:
            with plan.mesh:
                loss_p, grads_p = grad_of(params, batch, plan.rt)
        finally:
            for n, fn in saved.items():
                setattr(ref_mod, n, fn)

        # K independent unpacked runs, one per document
        total, loss_acc = 0.0, 0.0
        grad_acc = jax.tree.map(lambda x: np.zeros(x.shape, np.float64),
                                params)
        docs = [d for seq_docs in data.documents(0) for d in seq_docs]
        assert len(docs) >= 3, len(docs)
        with plan0.mesh:
            for d in docs:
                db = {k: jnp.asarray(d[k][None]) for k in
                      ("tokens", "labels", "positions")}
                loss_d, grads_d = grad_of(params, db, plan0.rt)
                n_d = float((d["labels"] >= 0).sum())
                total += n_d
                loss_acc += n_d * float(loss_d)
                grad_acc = jax.tree.map(
                    lambda a, g: a + n_d * np.asarray(g, np.float64),
                    grad_acc, grads_d)
        loss_ind = loss_acc / total
        grads_ind = jax.tree.map(lambda a: a / total, grad_acc)

        assert abs(float(loss_p) - loss_ind) < 1e-5, \
            (pc, float(loss_p), loss_ind)
        for a, b in zip(jax.tree.leaves(grads_p),
                        jax.tree.leaves(grads_ind)):
            assert err(a, b) < 1e-5, pc
    print("PASS packed_parity")


def check_grad_compression():
    """int8 error-feedback psum inside shard_map over the data axis."""
    from jax.sharding import PartitionSpec as P
    from repro.core.runtime import shard_map_compat as _shard_map
    from repro.core.topology import ParallelConfig, make_mesh, AXIS_DATA
    from repro.train.optimizer import compressed_psum

    pc = ParallelConfig(dp=8)
    mesh = make_mesh(pc)
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 64), jnp.float32)
    err_state = jnp.zeros((8, 64), jnp.float32)

    def local(g, e):
        s, e2 = compressed_psum(g, e, AXIS_DATA)
        return s, e2

    f = _shard_map(local, mesh, (P(AXIS_DATA, None), P(AXIS_DATA, None)),
                   (P(None, None), P(AXIS_DATA, None)))
    # accumulate over steps: error feedback should keep the running sum
    # close to the exact running sum
    exact_acc = np.zeros((1, 64))
    comp_acc = np.zeros((1, 64))
    e = err_state
    for step in range(20):
        g_step = jax.random.normal(jax.random.PRNGKey(step), (8, 64))
        with mesh:
            s, e = f(g_step, e)
        exact_acc += np.asarray(g_step).sum(0, keepdims=True)
        comp_acc += np.asarray(s)[:1]
    drift = np.abs(comp_acc - exact_acc).max() / np.abs(exact_acc).max()
    assert drift < 0.05, drift
    print("PASS grad_compression")


def check_ckpt_elastic():
    """Kill-and-resume loss parity across *different* plans: train K
    steps under plan A (dp=2, ZeRO extent 2), save, then restore under
    plan B (dp=4, extent 4) and continue — the stitched loss trace must
    match an uninterrupted plan-B run to 1e-5.  The manifest proves the
    saved and target extents differ, so the restore really resharded
    (elastic restart is a restore, not a migration)."""
    import shutil
    import tempfile
    from repro.configs import get_reduced
    from repro.core.plan import build_plan
    from repro.core.topology import ParallelConfig
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_reduced("qwen3-1.7b")
    S, GB, N, K = 64, 8, 8, 4

    def trainer(dp, ckpt_dir, num_steps, ckpt_every):
        plan = build_plan(cfg, ParallelConfig(dp=dp),
                          devices=jax.devices()[:dp], impl="ref",
                          seq_len=S, global_batch=GB, zero="dp")
        tcfg = TrainerConfig(num_steps=num_steps, ckpt_dir=ckpt_dir,
                             ckpt_every=ckpt_every, log_every=1000)
        return Trainer(plan, plan.data_config(S, GB), tcfg)

    d = tempfile.mkdtemp(prefix="ckpt_elastic_")
    try:
        base = trainer(4, None, N, 10**6).run()
        assert len(base) == N

        t_a = trainer(2, d, K, K)          # saves step K on its way out
        assert t_a.plan.mem["zero_extent"] == 2
        part1 = t_a.run()
        t_a.ckpter.flush()

        t_b = trainer(4, d, N, 10**6)      # auto-restores at step K
        assert t_b.plan.mem["zero_extent"] == 4
        assert t_b.start_step == K, t_b.start_step
        m = t_b.ckpter.manifest()
        assert m["plan"]["dp"] == 2 and m["plan"]["zero_extent"] == 2
        assert max(e["shards"] for e in m["leaves"]) > 1   # truly sharded
        part2 = t_b.run()

        got = part1 + part2
        assert len(got) == N, (len(part1), len(part2))
        for i, (a, b) in enumerate(zip(got, base)):
            assert abs(a - b) < 1e-5, (i, a, b)
    finally:
        shutil.rmtree(d, ignore_errors=True)
    print("PASS ckpt_elastic")


def check_offload_parity():
    """FPDT sequence-chunk pipeline (host KV offload) == resident
    double-ring: outputs and all three grads to 1e-5 on the ring 2x2,
    Ulysses hp=2 and combined hp×cp grids, zigzag on, on the Pallas
    kernel path (the jnp fallbacks are poisoned) — including packed
    documents whose boundaries straddle the chunk edges, the case the
    chunk-base BandMask shift exists for."""
    from repro.core.topology import ParallelConfig, make_mesh
    from repro.core.attention2d import (Attn2DConfig, attention_2d,
                                        chunked_attention_2d)
    from repro.core.zigzag import to_zigzag, from_zigzag
    from repro.kernels import ref as ref_mod
    from repro.runtime.offload import OffloadManager

    rng = np.random.default_rng(11)
    B, S, H, HKV, D, C = 1, 128, 4, 2, 16, 4
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, HKV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, HKV, D)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)

    # packed stream whose document boundaries straddle the chunk edges
    # (S=128, C=4 -> edges at 32/64/96; docs start at 20/50/90)
    starts = [0, 20, 50, 90]
    doc_np = np.zeros((B, S), np.int32)
    for s0, s1 in zip(starts, starts[1:] + [S]):
        doc_np[:, s0:s1] = s0
    doc = jnp.asarray(doc_np)

    def boom(*a, **kw):
        raise AssertionError("jnp fallback selected on the chunked path")

    poisoned = ("attention_ref_chunked", "attention_bwd_ref_chunked")
    saved = {n: getattr(ref_mod, n) for n in poisoned}

    grids = [("ring2x2", 1, 2, 2), ("ulysses_hp2", 2, 1, 1),
             ("combined", 2, 2, 2)]
    for tag, hp, no, wi in grids:
        pc = ParallelConfig(dp=1, hp=hp, cp_outer=no, cp_inner=wi)
        mesh = make_mesh(pc)
        cp = pc.cp
        cfg = Attn2DConfig(hp=hp, n_out=no, w=wi, causal=True,
                           impl="pallas_interpret")
        for docs in (None, doc):
            def resident(q, k, v):
                qz, kz, vz = (to_zigzag(x, cp) for x in (q, k, v))
                dz = None if docs is None else to_zigzag(docs, cp)
                with mesh:
                    out = attention_2d(qz, kz, vz, mesh=mesh, cfg=cfg,
                                       doc_start=dz)
                out = from_zigzag(out, cp)
                return (out * w).sum(), out

            with mesh:
                (loss_r, o_r), g_r = jax.value_and_grad(
                    resident, argnums=(0, 1, 2), has_aux=True)(q, k, v)

            for n in poisoned:
                setattr(ref_mod, n, boom)
            try:
                mgr = OffloadManager()
                with mesh:
                    o_c, vjp = chunked_attention_2d(
                        q, k, v, mesh=mesh, cfg=cfg, chunks=C,
                        doc_start=docs, offload=mgr)
                    g_c = vjp(w)           # loss = (out*w).sum => d_out = w
            finally:
                for n, fn in saved.items():
                    setattr(ref_mod, n, fn)

            packed = "packed" if docs is not None else "dense"
            loss_c = float((np.asarray(o_c, np.float64)
                            * np.asarray(w, np.float64)).sum())
            rel = abs(loss_c - float(loss_r)) / max(1.0, abs(float(loss_r)))
            assert rel < 1e-5, (tag, packed, loss_c, float(loss_r))
            assert err(o_c, o_r) < 1e-5, (tag, packed, err(o_c, o_r))
            for a, b in zip(g_c, g_r):
                assert err(a, b) < 1e-5, (tag, packed, err(a, b))
            assert mgr.stalls == 0, (tag, packed, mgr.stats())
    print("PASS offload_parity")


CHECKS = {name[len("check_"):]: fn for name, fn in list(globals().items())
          if name.startswith("check_")}

if __name__ == "__main__":
    CHECKS[sys.argv[1]]()
