"""Distributed equivalence tests — each runs a subprocess with 8 fake host
devices (device count is locked at first jax import in a process).

Marked ``dist`` so the CI fast tier can deselect the whole suite with
``-m 'not dist'`` instead of relying on ``-x`` ordering luck.
"""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.dist

_CHECKS = ["attention_grid", "attention_modes", "ring_pallas_path", "ssm",
           "moe", "e2e_loss", "decode_consistency", "grad_compression",
           "plan_placement", "accum_collectives", "packed_parity",
           "ckpt_elastic", "offload_parity"]


@pytest.mark.parametrize("check", _CHECKS)
def test_distributed(check):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    script = os.path.join(os.path.dirname(__file__), "_dist_checks.py")
    res = subprocess.run([sys.executable, script, check],
                         capture_output=True, text=True, timeout=1200,
                         env=env)
    assert res.returncode == 0, f"{check} failed:\n{res.stdout}\n{res.stderr}"
    assert f"PASS {check}" in res.stdout
