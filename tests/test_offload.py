"""OffloadManager invariants + single-device chunk-pipeline parity.

Property tests (hypothesis, or the deterministic stub in
``tests/_hypothesis_stub.py``) drive random chunk schedules against the
residency contract of ``repro/runtime/offload.py``:

* a consumer can never read a chunk before its H2D copy has landed
  (``get`` is the landing barrier, un-prefetched reads count a stall);
* manager-held device bytes never exceed a configured budget —
  oversubscription raises ``BudgetExceeded`` instead of silently
  spilling;
* put → prefetch → get round-trips are bitwise identity;
* the ``prefetched()`` double-buffer schedule runs stall-free.

The parity test at the bottom pins ``chunked_attention_2d`` (the FPDT
sequence-chunk pipeline) against the resident oracle on one device; the
multi-device grids live in ``tests/_dist_checks.py::check_offload_parity``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.runtime.offload import (HOST, BudgetExceeded, OffloadManager,
                                   prefetched)

RNG = np.random.default_rng(0)


def chunk(shape=(4, 8), dtype=np.float32, rng=RNG):
    return np.asarray(rng.standard_normal(shape), dtype)


# -- read-before-landing ----------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6),
       n_keys=st.integers(min_value=1, max_value=4),
       n_ops=st.integers(min_value=5, max_value=40))
def test_random_schedule_never_reads_unlanded(seed, n_keys, n_ops):
    """Whatever the schedule, ``get`` only ever returns a landed device
    array whose bytes equal the staged host copy."""
    rng = np.random.default_rng(seed)
    mgr = OffloadManager()
    model = {}
    for i in range(n_keys):
        arr = chunk(rng=rng)
        model[i] = arr
        mgr.put(i, arr)
    for _ in range(n_ops):
        key = int(rng.integers(0, n_keys))
        op = ("put", "prefetch", "get", "release")[int(rng.integers(0, 4))]
        if op == "put":
            model[key] = chunk(rng=rng)
            mgr.put(key, model[key])
        elif op == "prefetch":
            mgr.prefetch(key)
        elif op == "release":
            mgr.release(key)
        else:
            dev = mgr.get(key)
            e = mgr._entries[key]
            assert e.state == "device" and e.landed
            assert np.array_equal(np.asarray(dev), model[key])
    # accounting closes: resident bytes are exactly the device account
    assert mgr.device_bytes == sum(mgr._entries[k].nbytes
                                   for k in mgr.resident())
    assert mgr.device_bytes <= mgr.peak_device_bytes


# -- budget enforcement -----------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(budget_chunks=st.integers(min_value=1, max_value=3),
       n_keys=st.integers(min_value=2, max_value=6))
def test_budget_never_exceeded(budget_chunks, n_keys):
    one = chunk().nbytes
    mgr = OffloadManager(budget_bytes=budget_chunks * one)
    for i in range(n_keys):
        mgr.put(i, chunk())
    fetched = 0
    for i in range(n_keys):
        if fetched < budget_chunks:
            mgr.prefetch(i)
            fetched += 1
            assert mgr.device_bytes <= mgr.budget_bytes
        else:
            before = mgr.device_bytes
            with pytest.raises(BudgetExceeded):
                mgr.prefetch(i)
            # a refused fetch leaves the accounts (and the entry) untouched
            assert mgr.device_bytes == before
            assert mgr._entries[i].state == HOST
    # releasing frees budget for the chunk that was refused
    if n_keys > budget_chunks:
        mgr.release(0)
        mgr.prefetch(budget_chunks)
        assert mgr.device_bytes <= mgr.budget_bytes
    assert mgr.peak_device_bytes <= mgr.budget_bytes


# -- round-trip identity ----------------------------------------------------

@pytest.mark.parametrize("dtype", [np.float32, np.int32, jnp.bfloat16])
def test_roundtrip_bitwise_identity(dtype):
    if dtype is jnp.bfloat16:
        arr = np.asarray(jnp.asarray(RNG.standard_normal((3, 5)),
                                     jnp.bfloat16))
    else:
        arr = np.asarray(RNG.standard_normal((3, 5)) * 100, dtype)
    mgr = OffloadManager()
    mgr.put("x", arr)
    mgr.prefetch("x")
    dev = mgr.get("x")
    assert np.array_equal(np.asarray(dev), arr)
    mgr.release("x")
    assert np.array_equal(mgr.host_array("x"), arr)   # evict keeps host bits
    assert np.array_equal(np.asarray(mgr.get("x")), arr)  # and refetches


def test_accumulate_sums_on_host():
    mgr = OffloadManager()
    deltas = [chunk() for _ in range(4)]
    for d in deltas:
        mgr.accumulate("dk", d)
    np.testing.assert_allclose(mgr.host_array("dk"),
                               np.sum(deltas, axis=0), rtol=1e-6)
    assert mgr.device_bytes == 0      # accumulation never touches HBM


# -- double-buffer schedule -------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(n_keys=st.integers(min_value=1, max_value=8),
       depth=st.integers(min_value=1, max_value=3))
def test_prefetched_schedule_is_stall_free(n_keys, depth):
    one = chunk().nbytes
    mgr = OffloadManager(budget_bytes=(depth + 1) * one)
    model = {}
    for i in range(n_keys):
        model[i] = chunk()
        mgr.put(i, model[i])
    seen = []
    for key, dev in prefetched(mgr, range(n_keys), depth=depth):
        seen.append(key)
        assert np.array_equal(np.asarray(dev), model[key])
    assert seen == list(range(n_keys))
    assert mgr.stalls == 0
    assert mgr.resident() == []       # release=True drained everything
    assert mgr.h2d_bytes == n_keys * one


def test_discard_returns_bytes():
    mgr = OffloadManager()
    mgr.put("a", chunk())
    mgr.prefetch("a")
    mgr.get("a")
    mgr.discard("a")
    assert mgr.device_bytes == 0 and mgr.host_bytes == 0
    mgr.discard("a")                  # idempotent


# -- single-device chunk-pipeline parity ------------------------------------

@pytest.mark.parametrize("chunks", [2, 4])
def test_chunked_attention_matches_resident(chunks):
    from repro.core.attention2d import Attn2DConfig, chunked_attention_2d
    from repro.core.topology import ParallelConfig, make_mesh
    from repro.kernels.ref import attention_ref

    rng = np.random.default_rng(7)
    B, S, H, HKV, D = 1, 64, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, HKV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, HKV, D)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)

    def oracle(q, k, v):
        out, _ = attention_ref(q, k, v, causal=True)
        return (out * w).sum(), out

    (_, o_ref), g_ref = jax.value_and_grad(
        oracle, argnums=(0, 1, 2), has_aux=True)(q, k, v)

    mesh = make_mesh(ParallelConfig())
    cfg = Attn2DConfig(impl="ref")
    mgr = OffloadManager()
    with mesh:
        out, vjp = chunked_attention_2d(q, k, v, mesh=mesh, cfg=cfg,
                                        chunks=chunks, offload=mgr)
        grads = vjp(w)               # loss = (out * w).sum  =>  d_out = w
    np.testing.assert_allclose(out, o_ref, atol=1e-5, rtol=1e-5)
    for a, b in zip(grads, g_ref):
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)
    assert mgr.stalls == 0           # the pipeline prefetches everything
