"""Decode-path units on one device: flash-decoding combine vs oracle,
ring-buffer cache semantics, cache growth invariants, serving shardings."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced
from repro.kernels.ref import attention_ref
from repro.models.attention_block import decode_attention
from repro.models.decode import grow_caches, init_caches
from repro.models.model import init_params


def test_decode_attention_matches_oracle(single_runtime):
    """Flash-decoding (banded mask, lse-combine) == dense oracle for a
    1-token query against a partially filled cache."""
    rt = single_runtime
    rng = np.random.default_rng(0)
    B, S, H, D = 2, 32, 4, 16
    q = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    pos = 20          # only positions 0..20 are valid
    with rt.mesh:
        out = decode_attention(q, k, v, jnp.int32(pos), rt)
    o_ref, _ = attention_ref(q, k[:, :pos + 1], v[:, :pos + 1])
    np.testing.assert_allclose(np.asarray(out), np.asarray(o_ref),
                               atol=1e-5, rtol=1e-5)


def test_decode_attention_replicated_kv(single_runtime):
    """MLA-style single logical KV head (kv_replicated=True)."""
    rt = single_runtime
    rng = np.random.default_rng(1)
    B, S, H, D = 1, 16, 4, 8
    q = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, 1, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, 1, D)), jnp.float32)
    with rt.mesh:
        out = decode_attention(q, k, v, jnp.int32(S - 1), rt,
                               kv_replicated=True)
    o_ref, _ = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(o_ref),
                               atol=1e-5, rtol=1e-5)


def test_decode_attention_ring_full(single_runtime):
    """Ring-buffer mode: all live slots attendable, order-invariant."""
    rt = single_runtime
    rng = np.random.default_rng(2)
    B, W, H, D = 1, 8, 2, 8
    q = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, W, H, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, W, H, D)), jnp.float32)
    with rt.mesh:
        out_full = decode_attention(q, k, v, jnp.int32(W - 1), rt,
                                    ring_full=jnp.int32(W))
        # permuting buffer slots must not change the output
        perm = jnp.asarray(np.random.default_rng(3).permutation(W))
        out_perm = decode_attention(q, k[:, perm], v[:, perm],
                                    jnp.int32(W - 1), rt,
                                    ring_full=jnp.int32(W))
    np.testing.assert_allclose(np.asarray(out_full), np.asarray(out_perm),
                               atol=1e-5, rtol=1e-5)
    o_ref, _ = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out_full), np.asarray(o_ref),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "gemma2-2b",
                                  "deepseek-v2-lite-16b", "zamba2-7b",
                                  "falcon-mamba-7b", "whisper-small"])
def test_cache_shapes_and_growth(arch):
    cfg = get_reduced(arch)
    caches = init_caches(cfg, b=2, s_max=16)
    grown = grow_caches(cfg, caches, 8)
    assert jax.tree.structure(caches) == jax.tree.structure(grown)
    for a, b in zip(jax.tree.leaves(caches), jax.tree.leaves(grown)):
        assert b.size >= a.size
        assert a.dtype == b.dtype
    # growing by 0 keeps attention caches identical in shape
    same = grow_caches(cfg, caches, 0)
    for a, b in zip(jax.tree.leaves(caches), jax.tree.leaves(same)):
        assert a.shape == b.shape


def test_window_cache_capped_at_window():
    cfg = get_reduced("gemma2-2b")          # window=16, pattern 2
    caches = init_caches(cfg, b=1, s_max=64)
    # local slot (0) capped at window; global slot (1) full length
    assert caches["blocks"][0]["k"].shape[2] == 16
    assert caches["blocks"][1]["k"].shape[2] == 64
    grown = grow_caches(cfg, caches, 100)
    assert grown["blocks"][0]["k"].shape[2] == 16      # never beyond window
    assert grown["blocks"][1]["k"].shape[2] == 164


def test_tp_shardings_never_exceed_model_axes(single_runtime):
    from repro.core.zero import tp_shardings
    cfg = get_reduced("qwen3-1.7b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    sh = tp_shardings(params, single_runtime.mesh)
    for s in jax.tree.leaves(sh):
        for axis in jax.tree_util.tree_leaves(tuple(s.spec)):
            assert axis in ("head", "outer", "inner")
