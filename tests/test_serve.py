"""Serving engine: sampler properties, paged-vs-contiguous decode parity
(bitwise), block allocator / scheduler units, engine end-to-end behaviour
and the no-recompilation guarantee."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_reduced
from repro.core.plan import build_plan
from repro.models.decode import PagedLayout, decode_step, init_caches
from repro.models.model import init_params
from repro.serve import (BlockAllocator, SamplingParams, Scheduler,
                         blocks_needed, init_paged_caches, sample_tokens)
from repro.serve.engine import EngineConfig, ServeEngine
from repro.serve.scheduler import DECODE, PREFILL, WAITING, Request


# ---------------------------------------------------------------------------
# Sampling
# ---------------------------------------------------------------------------

def _sample_one(logits, *, temperature=0.0, top_k=0, top_p=1.0, key=None,
                step=0):
    b = logits.shape[0]
    key = key if key is not None else jax.random.PRNGKey(0)
    return np.asarray(sample_tokens(
        jnp.asarray(logits), jnp.full((b,), temperature, jnp.float32),
        jnp.full((b,), top_k, jnp.int32), jnp.full((b,), top_p, jnp.float32),
        jnp.broadcast_to(jnp.asarray(key, jnp.uint32), (b, 2)),
        jnp.full((b,), step, jnp.int32)))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**20))
def test_temperature_zero_matches_greedy_argmax(seed):
    rng = np.random.default_rng(seed)
    logits = rng.standard_normal((3, 64)).astype(np.float32)
    toks = _sample_one(logits, temperature=0.0, top_k=7, top_p=0.3,
                       key=jax.random.PRNGKey(seed))
    np.testing.assert_array_equal(toks, logits.argmax(-1))


@settings(max_examples=10, deadline=None)
@given(k=st.integers(1, 16), seed=st.integers(0, 2**20))
def test_top_k_support(k, seed):
    """Sampled tokens always come from the k largest logits."""
    rng = np.random.default_rng(seed)
    logits = rng.standard_normal((4, 64)).astype(np.float32)
    for step in range(5):
        toks = _sample_one(logits, temperature=1.0, top_k=k,
                           key=jax.random.PRNGKey(seed), step=step)
        topk = np.argsort(logits, axis=-1)[:, -k:]
        for b, t in enumerate(toks):
            assert t in topk[b], (k, t)


@settings(max_examples=10, deadline=None)
@given(p=st.sampled_from([0.05, 0.3, 0.7, 0.95]), seed=st.integers(0, 2**20))
def test_top_p_mass(p, seed):
    """Sampled tokens lie in the smallest prefix of the sorted
    distribution whose (exclusive) mass is below p — the nucleus."""
    rng = np.random.default_rng(seed)
    logits = (3.0 * rng.standard_normal((4, 32))).astype(np.float32)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    for step in range(5):
        toks = _sample_one(logits, temperature=1.0, top_p=p,
                           key=jax.random.PRNGKey(seed), step=step)
        for b, t in enumerate(toks):
            order = np.argsort(-probs[b])
            cum = np.cumsum(probs[b][order]) - probs[b][order]
            nucleus = set(order[cum < p])
            assert t in nucleus, (p, t, sorted(nucleus))


def test_sampling_streams_reproducible_and_distinct():
    rng = np.random.default_rng(0)
    logits = rng.standard_normal((1, 128)).astype(np.float32)
    a = [_sample_one(logits, temperature=1.0, key=jax.random.PRNGKey(7),
                     step=s)[0] for s in range(20)]
    b = [_sample_one(logits, temperature=1.0, key=jax.random.PRNGKey(7),
                     step=s)[0] for s in range(20)]
    c = [_sample_one(logits, temperature=1.0, key=jax.random.PRNGKey(8),
                     step=s)[0] for s in range(20)]
    assert a == b                 # same stream → same draws
    assert a != c                 # different stream → different draws
    assert len(set(a)) > 1        # per-step fold actually varies


# ---------------------------------------------------------------------------
# Block allocator / scheduler
# ---------------------------------------------------------------------------

def test_block_allocator_freelist():
    alloc = BlockAllocator(8)
    a = alloc.alloc(3)
    b = alloc.alloc(5)
    assert sorted(a + b) == list(range(8))
    assert alloc.alloc(1) is None          # exhausted
    alloc.free(a)
    assert alloc.free_blocks == 3
    c = alloc.alloc(3)
    assert sorted(c) == sorted(a)          # recycled
    with pytest.raises(ValueError):
        alloc.free(c + c[:1])              # double free
    assert blocks_needed(33, 16) == 3


def _req(rid, prompt_len=16, max_new=8):
    return Request(rid=rid, prompt=np.zeros(prompt_len, np.int32),
                   sampling=SamplingParams(), max_new_tokens=max_new)


def test_scheduler_admission_is_fifo_and_block_bounded():
    alloc = BlockAllocator(6)
    sched = Scheduler(max_batch=2, allocator=alloc, page_size=8,
                      max_blocks_per_seq=4)
    r1, r2, r3 = _req(1), _req(2), _req(3)       # 24 tokens → 3 blocks each
    for r in (r1, r2, r3):
        sched.submit(r)
    admitted = sched.admit()
    assert admitted == [r1, r2]                   # slots exhausted
    assert r3.state == WAITING
    assert alloc.free_blocks == 0
    r1.state = DECODE
    sched.retire(r1)
    assert sched.admit() == [r3]                  # blocks + slot recycled
    assert sched.slots[r3.slot] is r3

    with pytest.raises(ValueError):               # over max_blocks_per_seq
        sched.submit(_req(4, prompt_len=40, max_new=8))


def test_scheduler_eviction_returns_to_queue_head():
    alloc = BlockAllocator(8)
    sched = Scheduler(max_batch=2, allocator=alloc, page_size=8,
                      max_blocks_per_seq=4)
    r1, r2 = _req(1), _req(2)
    sched.submit(r1)
    sched.submit(r2)
    sched.admit()
    held = alloc.free_blocks
    sched.evict(r1)
    assert r1.state == WAITING and r1.blocks == []
    assert alloc.free_blocks > held
    assert sched.waiting[0] is r1                 # head of queue
    assert sched.admit() == [r1]                  # re-admitted first
    assert r1.state == PREFILL


# ---------------------------------------------------------------------------
# Paged vs contiguous decode: bitwise parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen3-1.7b", "gemma2-2b",
                                  "deepseek-v2-lite-16b"])
def test_paged_decode_bitwise_equals_contiguous(arch):
    """For the same ragged stream, decode through block-table pools is
    bitwise identical to decode through contiguous caches: the gathered
    view reconstructs the exact contiguous tensor, so every downstream op
    sees identical inputs.  Covers full-attention GQA, sliding-window
    ring buffers, and the absorbed-MLA latent cache."""
    cfg = get_reduced(arch)
    plan = build_plan(cfg, devices=jax.devices()[:1], impl="ref")
    rt = plan.rt
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, PAGE, MAXB = 2, 8, 6
    S = PAGE * MAXB                    # contiguous extent == gathered view
    NB = B * MAXB
    rng = np.random.default_rng(0)

    cont = init_caches(cfg, B, S)
    pools = init_paged_caches(cfg, num_blocks=NB, page_size=PAGE,
                              max_batch=B)
    # identity-layout block tables: request b owns blocks [b*MAXB, ...)
    btabs = jnp.asarray(np.arange(NB).reshape(B, MAXB), jnp.int32)
    paged = PagedLayout(btabs, PAGE, NB)

    lengths = np.array([0, 3], np.int32)          # ragged from the start
    with plan.mesh:
        for step in range(6):
            toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(B, 1)),
                               jnp.int32)
            pos = jnp.asarray(lengths)
            lg_c, cont = decode_step(params, cont, toks, pos, rt, cfg)
            lg_p, pools = decode_step(params, pools, toks, pos, rt, cfg,
                                      paged)
            np.testing.assert_array_equal(np.asarray(lg_c),
                                          np.asarray(lg_p),
                                          err_msg=f"{arch} step {step}")
            lengths += 1


def test_kv_start_masks_key_prefix():
    """``kv_start`` bounds the visible key range from below — equivalent
    to slicing the leading keys off, scalar or per-request."""
    from repro.kernels.ref import attention_ref
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((2, 4, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 12, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 12, 2, 8)), jnp.float32)
    out, _ = attention_ref(q, k, v, kv_start=3)
    o_ref, _ = attention_ref(q, k[:, 3:], v[:, 3:])
    np.testing.assert_allclose(np.asarray(out), np.asarray(o_ref),
                               atol=1e-6, rtol=1e-6)
    starts = jnp.asarray([3, 5], jnp.int32)       # ragged per-request
    out_b, _ = attention_ref(q, k, v, kv_start=starts)
    for b, s0 in enumerate((3, 5)):
        o_b, _ = attention_ref(q[b:b + 1], k[b:b + 1, s0:],
                               v[b:b + 1, s0:])
        np.testing.assert_allclose(np.asarray(out_b[b:b + 1]),
                                   np.asarray(o_b), atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------------------
# Engine end-to-end
# ---------------------------------------------------------------------------

def _engine_setup(arch, max_batch=2, page=8, maxb=8, prefill_chunk=16):
    cfg = get_reduced(arch)
    plan = build_plan(cfg, devices=jax.devices()[:1], impl="ref")
    params = init_params(cfg, jax.random.PRNGKey(0))
    spec = EngineConfig(page_size=page, num_blocks=max_batch * maxb,
                        max_blocks_per_seq=maxb, max_batch=max_batch,
                        prefill_chunk=prefill_chunk)
    return cfg, plan, params, spec


@pytest.mark.parametrize("arch,prompt_len", [("qwen3-1.7b", 24),
                                             ("gemma2-2b", 32),
                                             ("deepseek-v2-lite-16b", 24)])
def test_engine_greedy_matches_fixed_baseline(arch, prompt_len):
    """Continuous-batching greedy output token-for-token equals the
    fixed-batch contiguous baseline (gemma2 at a window-divisible prompt,
    the baseline ring buffer's documented precondition)."""
    from repro.launch.serve import generate
    cfg, plan, params, spec = _engine_setup(arch)
    rng = np.random.default_rng(0)
    B, GEN = 2, 6
    prompts = rng.integers(0, cfg.vocab, size=(B, prompt_len))
    with plan.mesh:
        base = np.asarray(generate(params, cfg, plan.rt,
                                   jnp.asarray(prompts), gen=GEN))
        eng = ServeEngine(plan, params, spec)
        for b in range(B):
            eng.submit(prompts[b], SamplingParams(), max_new_tokens=GEN)
        res = eng.run()
    for b in range(B):
        assert res["requests"][b]["tokens"] == list(base[b]), arch


def test_engine_continuous_batching_mixed_lengths():
    """More requests than slots, ragged prompts and gen lengths: everyone
    finishes with exactly its requested token count, pages are recycled,
    and the pool ends fully free."""
    cfg, plan, params, spec = _engine_setup("qwen3-1.7b", max_batch=2)
    rng = np.random.default_rng(1)
    lens = [(10, 3), (25, 9), (7, 5), (40, 2), (18, 7)]
    with plan.mesh:
        eng = ServeEngine(plan, params, spec)
        for p_len, gen in lens:
            eng.submit(rng.integers(0, cfg.vocab, size=p_len),
                       SamplingParams(temperature=0.7, top_k=20, seed=3),
                       max_new_tokens=gen)
        res = eng.run()
    for rid, (p_len, gen) in enumerate(lens):
        assert len(res["requests"][rid]["tokens"]) == gen
    assert eng.alloc.free_blocks == spec.num_blocks
    assert all(r is None for r in eng.sched.slots)


def test_engine_no_recompilation_across_stream():
    """After warmup, a full mixed stream triggers zero new traces of the
    decode step or any prefill bucket — bucketed shapes + pre-sized block
    reservation keep every jit cache-hit (the grow_caches retrace bug
    class, fixed)."""
    cfg, plan, params, spec = _engine_setup("qwen3-1.7b", max_batch=2,
                                            maxb=8, prefill_chunk=16)
    rng = np.random.default_rng(2)
    with plan.mesh:
        eng = ServeEngine(plan, params, spec)
        eng.warmup(prompt_lens=(16, 32), max_new=3)
        decode_traces = eng.decode_traces
        prefill_traces = dict(eng.prefill_traces)
        assert decode_traces >= 1
        for i in range(5):
            eng.submit(rng.integers(0, cfg.vocab, size=8 + 5 * i),
                       SamplingParams(), max_new_tokens=4 + i)
        eng.run()
        assert eng.decode_traces == decode_traces
        assert set(eng.prefill_traces) == set(prefill_traces)


def test_generate_single_decode_trace():
    """The fixed-batch baseline pre-sizes caches to prompt+gen before the
    loop: decode_step traces exactly once for the whole stream."""
    from repro.launch.serve import generate
    cfg = get_reduced("qwen3-1.7b")
    plan = build_plan(cfg, devices=jax.devices()[:1], impl="ref")
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, size=(2, 12)), jnp.int32)
    with plan.mesh:
        out, traces = generate(params, cfg, plan.rt, tokens, gen=8,
                               return_stats=True)
    assert out.shape == (2, 8)
    assert traces == {"prefill": 1, "decode": 1}


def test_engine_evict_restarts_cleanly():
    """Evicting a mid-decode request releases its pages, masks its slot,
    and the re-admitted run reproduces the uninterrupted greedy output."""
    cfg, plan, params, spec = _engine_setup("qwen3-1.7b", max_batch=1)
    rng = np.random.default_rng(4)
    p1 = rng.integers(0, cfg.vocab, size=12)
    with plan.mesh:
        eng = ServeEngine(plan, params, spec)
        r1 = eng.submit(p1, SamplingParams(), max_new_tokens=8)
        r2 = eng.submit(rng.integers(0, cfg.vocab, size=10),
                        SamplingParams(), max_new_tokens=4)
        for _ in range(4):
            eng.step()
        assert eng.requests[r1].state == DECODE
        eng.evict(r1)
        assert eng.alloc.free_blocks == spec.num_blocks
        assert eng.sched.waiting[0] is eng.requests[r1]
        res = eng.run()
        assert len(res["requests"][r1]["tokens"]) == 8
        assert len(res["requests"][r2]["tokens"]) == 4
        eng2 = ServeEngine(plan, params, spec)
        rid = eng2.submit(p1, SamplingParams(), max_new_tokens=8)
        res2 = eng2.run()
    assert res["requests"][r1]["tokens"] == res2["requests"][rid]["tokens"]


def test_engine_eos_stops_early():
    cfg, plan, params, spec = _engine_setup("qwen3-1.7b")
    prompt = np.random.default_rng(3).integers(0, cfg.vocab, size=12)
    with plan.mesh:
        eng = ServeEngine(plan, params, spec)
        rid = eng.submit(prompt, SamplingParams(), max_new_tokens=50)
        res = eng.run()
        toks = res["requests"][rid]["tokens"]
        eos = toks[2]                      # force an early stop on rerun
        eng.requests.clear()
        rid2 = eng.submit(prompt, SamplingParams(), max_new_tokens=50,
                          eos_id=eos)
        res2 = eng.run()
    got = res2["requests"][rid2]["tokens"]
    assert got == toks[:3]                 # greedy → same prefix, then stop
    assert got[-1] == eos


# ---------------------------------------------------------------------------
# Serve-mode plan
# ---------------------------------------------------------------------------

def test_serve_spec_from_memory_model_and_describe():
    cfg = get_reduced("qwen3-1.7b")
    plan = build_plan(cfg, devices=jax.devices()[:1])
    sv = plan.serve_spec(page_size=16, max_batch=4, max_seq_len=1024)
    assert sv.max_blocks_per_seq == 64
    assert sv.num_blocks >= sv.max_blocks_per_seq
    assert sv.num_blocks <= 4 * 64            # capped at usable maximum
    # per-token bytes: 2 (k+v) * kv_heads * head_dim * 4B * layers
    assert sv.paged_bytes_per_token == \
        2 * cfg.n_kv_heads * cfg.hd * 4 * cfg.num_layers
    assert "serve" in plan.describe()
    assert f"page={sv.page_size}" in plan.describe()

    # tiny budget: the pool shrinks below the usable cap but never below
    # one full sequence
    small = build_plan(cfg, devices=jax.devices()[:1],
                       memory_budget_gb=0.0005)
    sv_small = small.serve_spec(page_size=16, max_batch=4,
                                max_seq_len=1024)
    assert sv_small.num_blocks == sv_small.max_blocks_per_seq

    # families without a paged decode path report n/a
    ssm_plan = build_plan(get_reduced("falcon-mamba-7b"),
                          devices=jax.devices()[:1])
    assert ssm_plan.serve_spec() is None
    assert "paged=n/a" in ssm_plan.describe()


def test_window_arch_serve_spec_accounts_ring_bytes():
    cfg = get_reduced("gemma2-2b")
    plan = build_plan(cfg, devices=jax.devices()[:1])
    sv = plan.serve_spec()
    assert sv.window_bytes > 0                 # local layers: fixed rings
    assert sv.paged_bytes_per_token > 0        # global layers: paged
